//! Explicit SIMD backends for the hot kernels — AVX2 and SSE2 on
//! x86_64, NEON on aarch64.
//!
//! **Bit-for-bit contract** (DESIGN.md §15): every function here must
//! produce exactly the bits of its [`super::scalar`] reference for every
//! input, including NaN payloads, signed zeros, infinities and
//! subnormals.  The rules that make that true by construction:
//!
//! * No fused multiply-add, ever.  The scalar kernels are written as
//!   separate IEEE-754 multiplies and adds (`gamma * v + g` rounds the
//!   product before the sum), so the vector code uses separate
//!   `mul`/`add` intrinsics — an FMA would change the rounding.
//! * No re-association.  Each lane evaluates the scalar expression in
//!   the scalar's exact operation order; remainders fall through to the
//!   scalar reference itself.
//! * Reductions keep the fixed 8-lane strided-accumulation shape of
//!   [`super::scalar`]: f64 lane `i` accumulates positions `8j + i`
//!   vertically, the tail is sequential, and the final fold is the same
//!   left-to-right `fold_acc`.  Lane counts below 8 (SSE2/NEON f64 is
//!   2-wide, AVX2 4-wide) just mean the 8 accumulators span several
//!   registers.
//! * The f16/bf16 converters are *integer* algorithms (exact by nature).
//!   The branch-heavy f16 special-case ladder is shipped as the scalar
//!   body recompiled under the target feature (multiversioned blocks);
//!   the branch-free bf16 conversions get real integer-SIMD fast paths
//!   where the ISA makes them cheap (AVX2, NEON).  Either way the bits
//!   are pinned against scalar by `rust/tests/kernels.rs`.
//!
//! Every function is `unsafe fn` with the same narrow contract: the
//! caller must have verified the ISA feature is available (the dispatch
//! layer in [`super`] only selects a backend after runtime detection,
//! and the safe `available()` probes gate direct use in tests).

#![allow(clippy::missing_safety_doc)] // every fn carries the module-level contract below
#![allow(clippy::too_many_arguments)] // kernel signatures mirror scalar's

/// Generates the f32 elementwise kernels, the fixed-8-lane reductions,
/// and the multiversioned f16 conversion blocks for one ISA module.
/// The module must define, above the invocation:
///   `LANES`, `type Vf`, `loadf/storef/splatf/vadd/vsub/vmul`,
///   `DLANES`, `type Vd`, `dzero/dadd/dsub/dmul/dload8/dstore8`.
macro_rules! isa_kernels {
    ($feat:literal) => {
        /// y += a * x (see module contract).
        #[target_feature(enable = $feat)]
        pub unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
            debug_assert_eq!(y.len(), x.len());
            let n = y.len();
            let main = n & !(LANES - 1);
            let av = splatf(a);
            let yp = y.as_mut_ptr();
            let xp = x.as_ptr();
            let mut i = 0;
            while i < main {
                let yv = loadf(yp.add(i));
                let xv = loadf(xp.add(i));
                storef(yp.add(i), vadd(yv, vmul(av, xv)));
                i += LANES;
            }
            crate::math::scalar::axpy(&mut y[main..], a, &x[main..]);
        }

        /// `v = gamma*v + g; theta -= eta*v` (Eq 2).
        #[target_feature(enable = $feat)]
        pub unsafe fn momentum_step(
            theta: &mut [f32],
            v: &mut [f32],
            g: &[f32],
            gamma: f32,
            eta: f32,
        ) {
            debug_assert!(theta.len() == v.len() && v.len() == g.len());
            let n = theta.len();
            let main = n & !(LANES - 1);
            let gv = splatf(gamma);
            let ev = splatf(eta);
            let tp = theta.as_mut_ptr();
            let vp = v.as_mut_ptr();
            let gp = g.as_ptr();
            let mut i = 0;
            while i < main {
                let vn = vadd(vmul(gv, loadf(vp.add(i))), loadf(gp.add(i)));
                storef(vp.add(i), vn);
                storef(tp.add(i), vsub(loadf(tp.add(i)), vmul(ev, vn)));
                i += LANES;
            }
            crate::math::scalar::momentum_step(
                &mut theta[main..],
                &mut v[main..],
                &g[main..],
                gamma,
                eta,
            );
        }

        /// Fused DANA-Zero master step (Eq 10/11 + Appendix A.2).
        #[target_feature(enable = $feat)]
        pub unsafe fn dana_fused_update(
            theta: &mut [f32],
            v: &mut [f32],
            vsum: &mut [f32],
            g: &[f32],
            gamma: f32,
            eta: f32,
        ) {
            debug_assert!(
                theta.len() == v.len() && v.len() == vsum.len() && vsum.len() == g.len()
            );
            let n = theta.len();
            let main = n & !(LANES - 1);
            let gammav = splatf(gamma);
            let etav = splatf(eta);
            let tp = theta.as_mut_ptr();
            let vp = v.as_mut_ptr();
            let sp = vsum.as_mut_ptr();
            let gp = g.as_ptr();
            let mut i = 0;
            while i < main {
                let vold = loadf(vp.add(i));
                let v_new = vadd(vmul(gammav, vold), loadf(gp.add(i)));
                storef(tp.add(i), vsub(loadf(tp.add(i)), vmul(etav, v_new)));
                storef(sp.add(i), vadd(loadf(sp.add(i)), vsub(v_new, vold)));
                storef(vp.add(i), v_new);
                i += LANES;
            }
            crate::math::scalar::dana_fused_update(
                &mut theta[main..],
                &mut v[main..],
                &mut vsum[main..],
                &g[main..],
                gamma,
                eta,
            );
        }

        /// DANA-DC fused apply (Alg 7): `ghat = g + ((lambda*g)*g)*(t-s)`
        /// in the scalar's left-associated order, then the DANA step.
        #[target_feature(enable = $feat)]
        pub unsafe fn dc_dana_fused_update(
            theta: &mut [f32],
            v: &mut [f32],
            vsum: &mut [f32],
            g: &[f32],
            sent: &[f32],
            gamma: f32,
            eta: f32,
            lambda: f32,
        ) {
            debug_assert!(
                theta.len() == v.len()
                    && v.len() == vsum.len()
                    && vsum.len() == g.len()
                    && g.len() == sent.len()
            );
            let n = theta.len();
            let main = n & !(LANES - 1);
            let gammav = splatf(gamma);
            let etav = splatf(eta);
            let lambdav = splatf(lambda);
            let tp = theta.as_mut_ptr();
            let vp = v.as_mut_ptr();
            let sp = vsum.as_mut_ptr();
            let gp = g.as_ptr();
            let sentp = sent.as_ptr();
            let mut i = 0;
            while i < main {
                let gv = loadf(gp.add(i));
                let told = loadf(tp.add(i));
                let corr = vmul(vmul(vmul(lambdav, gv), gv), vsub(told, loadf(sentp.add(i))));
                let ghat = vadd(gv, corr);
                let vold = loadf(vp.add(i));
                let v_new = vadd(vmul(gammav, vold), ghat);
                storef(tp.add(i), vsub(told, vmul(etav, v_new)));
                storef(sp.add(i), vadd(loadf(sp.add(i)), vsub(v_new, vold)));
                storef(vp.add(i), v_new);
                i += LANES;
            }
            crate::math::scalar::dc_dana_fused_update(
                &mut theta[main..],
                &mut v[main..],
                &mut vsum[main..],
                &g[main..],
                &sent[main..],
                gamma,
                eta,
                lambda,
            );
        }

        /// `hat = theta - (eta*gamma)*vsum` (Eq 11).
        #[target_feature(enable = $feat)]
        pub unsafe fn lookahead(
            hat: &mut [f32],
            theta: &[f32],
            vsum: &[f32],
            gamma: f32,
            eta: f32,
        ) {
            debug_assert!(hat.len() == theta.len() && theta.len() == vsum.len());
            let n = hat.len();
            let main = n & !(LANES - 1);
            let cv = splatf(eta * gamma);
            let hp = hat.as_mut_ptr();
            let tp = theta.as_ptr();
            let sp = vsum.as_ptr();
            let mut i = 0;
            while i < main {
                storef(hp.add(i), vsub(loadf(tp.add(i)), vmul(cv, loadf(sp.add(i)))));
                i += LANES;
            }
            crate::math::scalar::lookahead(
                &mut hat[main..],
                &theta[main..],
                &vsum[main..],
                gamma,
                eta,
            );
        }

        /// Extrapolated look-ahead: `depth` momentum-only steps per lane,
        /// then Eq 11 at the extrapolated point.
        #[target_feature(enable = $feat)]
        pub unsafe fn lookahead_extrapolated(
            hat: &mut [f32],
            theta: &[f32],
            vsum: &[f32],
            gamma: f32,
            eta: f32,
            depth: usize,
        ) {
            debug_assert!(hat.len() == theta.len() && theta.len() == vsum.len());
            let n = hat.len();
            let main = n & !(LANES - 1);
            let gammav = splatf(gamma);
            let etav = splatf(eta);
            let cv = splatf(eta * gamma);
            let hp = hat.as_mut_ptr();
            let tp = theta.as_ptr();
            let sp = vsum.as_ptr();
            let mut i = 0;
            while i < main {
                let mut t = loadf(tp.add(i));
                let mut v = loadf(sp.add(i));
                for _ in 0..depth {
                    v = vmul(gammav, v);
                    t = vsub(t, vmul(etav, v));
                }
                storef(hp.add(i), vsub(t, vmul(cv, v)));
                i += LANES;
            }
            crate::math::scalar::lookahead_extrapolated(
                &mut hat[main..],
                &theta[main..],
                &vsum[main..],
                gamma,
                eta,
                depth,
            );
        }

        /// `g += ((lambda*g)*g)*(tm - ts)` (Eq 17, scalar association).
        #[target_feature(enable = $feat)]
        pub unsafe fn dc_adjust(
            g: &mut [f32],
            theta_master: &[f32],
            theta_sent: &[f32],
            lambda: f32,
        ) {
            debug_assert!(g.len() == theta_master.len() && g.len() == theta_sent.len());
            let n = g.len();
            let main = n & !(LANES - 1);
            let lambdav = splatf(lambda);
            let gp = g.as_mut_ptr();
            let mp = theta_master.as_ptr();
            let sp = theta_sent.as_ptr();
            let mut i = 0;
            while i < main {
                let gv = loadf(gp.add(i));
                let dv = vsub(loadf(mp.add(i)), loadf(sp.add(i)));
                let corr = vmul(vmul(vmul(lambdav, gv), gv), dv);
                storef(gp.add(i), vadd(gv, corr));
                i += LANES;
            }
            crate::math::scalar::dc_adjust(
                &mut g[main..],
                &theta_master[main..],
                &theta_sent[main..],
                lambda,
            );
        }

        /// DANA-Slim in-place worker update: `v = gamma*v + g` then
        /// `g = gamma*v_new + g` (old g read before overwrite).
        #[target_feature(enable = $feat)]
        pub unsafe fn slim_worker_update_inplace(v: &mut [f32], g: &mut [f32], gamma: f32) {
            debug_assert_eq!(v.len(), g.len());
            let n = v.len();
            let main = n & !(LANES - 1);
            let gammav = splatf(gamma);
            let vp = v.as_mut_ptr();
            let gp = g.as_mut_ptr();
            let mut i = 0;
            while i < main {
                let gv = loadf(gp.add(i));
                let v_new = vadd(vmul(gammav, loadf(vp.add(i))), gv);
                storef(vp.add(i), v_new);
                storef(gp.add(i), vadd(vmul(gammav, v_new), gv));
                i += LANES;
            }
            crate::math::scalar::slim_worker_update_inplace(&mut v[main..], &mut g[main..], gamma);
        }

        /// dot(a, b): fixed 8-lane strided f64 accumulation (lane `i`
        /// sums positions `8j + i`), sequential tail, scalar fold order.
        #[target_feature(enable = $feat)]
        pub unsafe fn dot(a: &[f32], b: &[f32]) -> f64 {
            debug_assert_eq!(a.len(), b.len());
            let n = a.len();
            let main = n & !(crate::math::scalar::REDUCE_LANES - 1);
            let mut acc = [dzero(); crate::math::scalar::REDUCE_LANES / DLANES];
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < main {
                let av = dload8(ap.add(i));
                let bv = dload8(bp.add(i));
                for j in 0..acc.len() {
                    acc[j] = dadd(acc[j], dmul(av[j], bv[j]));
                }
                i += crate::math::scalar::REDUCE_LANES;
            }
            let mut lanes = [0.0f64; crate::math::scalar::REDUCE_LANES];
            dstore8(&mut lanes, acc);
            let mut tail = 0.0;
            for k in main..n {
                tail += a[k] as f64 * b[k] as f64;
            }
            crate::math::scalar::fold_acc(&lanes) + tail
        }

        /// ||a||² with the same fixed 8-lane shape as [`dot`].
        #[target_feature(enable = $feat)]
        pub unsafe fn norm2_sq(a: &[f32]) -> f64 {
            let n = a.len();
            let main = n & !(crate::math::scalar::REDUCE_LANES - 1);
            let mut acc = [dzero(); crate::math::scalar::REDUCE_LANES / DLANES];
            let ap = a.as_ptr();
            let mut i = 0;
            while i < main {
                let av = dload8(ap.add(i));
                for j in 0..acc.len() {
                    acc[j] = dadd(acc[j], dmul(av[j], av[j]));
                }
                i += crate::math::scalar::REDUCE_LANES;
            }
            let mut lanes = [0.0f64; crate::math::scalar::REDUCE_LANES];
            dstore8(&mut lanes, acc);
            let mut tail = 0.0;
            for k in main..n {
                tail += a[k] as f64 * a[k] as f64;
            }
            crate::math::scalar::fold_acc(&lanes) + tail
        }

        /// ||a - b||² with the same fixed 8-lane shape as [`dot`].
        #[target_feature(enable = $feat)]
        pub unsafe fn sub_norm_sq(a: &[f32], b: &[f32]) -> f64 {
            debug_assert_eq!(a.len(), b.len());
            let n = a.len();
            let main = n & !(crate::math::scalar::REDUCE_LANES - 1);
            let mut acc = [dzero(); crate::math::scalar::REDUCE_LANES / DLANES];
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            let mut i = 0;
            while i < main {
                let av = dload8(ap.add(i));
                let bv = dload8(bp.add(i));
                for j in 0..acc.len() {
                    let d = dsub(av[j], bv[j]);
                    acc[j] = dadd(acc[j], dmul(d, d));
                }
                i += crate::math::scalar::REDUCE_LANES;
            }
            let mut lanes = [0.0f64; crate::math::scalar::REDUCE_LANES];
            dstore8(&mut lanes, acc);
            let mut tail = 0.0;
            for k in main..n {
                let d = a[k] as f64 - b[k] as f64;
                tail += d * d;
            }
            crate::math::scalar::fold_acc(&lanes) + tail
        }

        /// f16 encode: the scalar special-case ladder recompiled under
        /// this ISA (multiversioned block — exact by construction; the
        /// normal-range fast path vectorizes, the ladder stays scalar).
        #[target_feature(enable = $feat)]
        pub unsafe fn f16_encode_into(out: &mut Vec<u8>, vals: &[f32]) {
            crate::math::scalar::f16_encode_into(out, vals);
        }

        /// f16 decode (multiversioned block, see [`f16_encode_into`]).
        #[target_feature(enable = $feat)]
        pub unsafe fn f16_decode_into(out: &mut Vec<f32>, bytes: &[u8]) {
            crate::math::scalar::f16_decode_into(out, bytes);
        }

        /// f16 quantize–dequantize in place (multiversioned block).
        #[target_feature(enable = $feat)]
        pub unsafe fn f16_round_trip(g: &mut [f32]) {
            crate::math::scalar::f16_round_trip(g);
        }

        /// bf16 quantize–dequantize in place, via this module's
        /// encode/decode bit kernels' shared scalar reference.
        #[target_feature(enable = $feat)]
        pub unsafe fn bf16_round_trip(g: &mut [f32]) {
            crate::math::scalar::bf16_round_trip(g);
        }
    };
}

#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use std::arch::x86_64::*;

    /// Runtime probe — callers must check before touching anything else
    /// in this module.
    pub fn available() -> bool {
        is_x86_feature_detected!("avx2")
    }

    const LANES: usize = 8;
    const DLANES: usize = 4;

    #[inline(always)]
    unsafe fn loadf(p: *const f32) -> __m256 {
        _mm256_loadu_ps(p)
    }
    #[inline(always)]
    unsafe fn storef(p: *mut f32, v: __m256) {
        _mm256_storeu_ps(p, v)
    }
    #[inline(always)]
    unsafe fn splatf(a: f32) -> __m256 {
        _mm256_set1_ps(a)
    }
    #[inline(always)]
    unsafe fn vadd(a: __m256, b: __m256) -> __m256 {
        _mm256_add_ps(a, b)
    }
    #[inline(always)]
    unsafe fn vsub(a: __m256, b: __m256) -> __m256 {
        _mm256_sub_ps(a, b)
    }
    #[inline(always)]
    unsafe fn vmul(a: __m256, b: __m256) -> __m256 {
        _mm256_mul_ps(a, b)
    }

    #[inline(always)]
    unsafe fn dzero() -> __m256d {
        _mm256_setzero_pd()
    }
    #[inline(always)]
    unsafe fn dadd(a: __m256d, b: __m256d) -> __m256d {
        _mm256_add_pd(a, b)
    }
    #[inline(always)]
    unsafe fn dsub(a: __m256d, b: __m256d) -> __m256d {
        _mm256_sub_pd(a, b)
    }
    #[inline(always)]
    unsafe fn dmul(a: __m256d, b: __m256d) -> __m256d {
        _mm256_mul_pd(a, b)
    }
    /// 8 consecutive f32 → two f64×4 groups, order-preserving.
    #[inline(always)]
    unsafe fn dload8(p: *const f32) -> [__m256d; 2] {
        let v = _mm256_loadu_ps(p);
        [
            _mm256_cvtps_pd(_mm256_castps256_ps128(v)),
            _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(v)),
        ]
    }
    #[inline(always)]
    unsafe fn dstore8(out: &mut [f64; 8], acc: [__m256d; 2]) {
        _mm256_storeu_pd(out.as_mut_ptr(), acc[0]);
        _mm256_storeu_pd(out.as_mut_ptr().add(4), acc[1]);
    }

    isa_kernels!("avx2");

    /// bf16 encode, 8 lanes per iteration: the scalar round-to-nearest-
    /// even add (`b + 0x7fff + ((b>>16)&1)`) and quiet-NaN forcing
    /// (`(b>>16)|0x40`) as integer SIMD, narrowed and stored LE.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_encode_into(out: &mut Vec<u8>, vals: &[f32]) {
        let n = vals.len();
        let start = out.len();
        out.reserve(2 * n);
        let dst = out.as_mut_ptr().add(start);
        let src = vals.as_ptr();
        let round = _mm256_set1_epi32(0x7fff);
        let one = _mm256_set1_epi32(1);
        let expmask = _mm256_set1_epi32(0x7f80_0000u32 as i32);
        let manmask = _mm256_set1_epi32(0x007f_ffff);
        let quiet = _mm256_set1_epi32(0x40);
        let mut i = 0;
        while i + 8 <= n {
            let b = _mm256_loadu_si256(src.add(i) as *const __m256i);
            let lsb = _mm256_and_si256(_mm256_srli_epi32::<16>(b), one);
            let r = _mm256_add_epi32(b, _mm256_add_epi32(round, lsb));
            let q = _mm256_srli_epi32::<16>(r);
            // NaN lanes: exponent all-ones and a nonzero mantissa
            let isexp = _mm256_cmpeq_epi32(_mm256_and_si256(b, expmask), expmask);
            let manz = _mm256_cmpeq_epi32(_mm256_and_si256(b, manmask), _mm256_setzero_si256());
            let nan = _mm256_andnot_si256(manz, isexp);
            let nanres = _mm256_or_si256(_mm256_srli_epi32::<16>(b), quiet);
            let res = _mm256_blendv_epi8(q, nanres, nan);
            // u32 lanes (≤ 0xffff) → 8 contiguous u16, little-endian
            let packed = _mm256_packus_epi32(res, res);
            let ordered = _mm256_permute4x64_epi64::<0b1000>(packed);
            _mm_storeu_si128(dst.add(2 * i) as *mut __m128i, _mm256_castsi256_si128(ordered));
            i += 8;
        }
        while i < n {
            let h = crate::math::scalar::f32_to_bf16(*src.add(i)).to_le_bytes();
            *dst.add(2 * i) = h[0];
            *dst.add(2 * i + 1) = h[1];
            i += 1;
        }
        out.set_len(start + 2 * n);
    }

    /// bf16 decode, 8 lanes per iteration: widen u16→u32, shift left 16.
    #[target_feature(enable = "avx2")]
    pub unsafe fn bf16_decode_into(out: &mut Vec<f32>, bytes: &[u8]) {
        debug_assert_eq!(bytes.len() % 2, 0);
        let n = bytes.len() / 2;
        let start = out.len();
        out.reserve(n);
        let dst = out.as_mut_ptr().add(start);
        let src = bytes.as_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let h = _mm_loadu_si128(src.add(2 * i) as *const __m128i);
            let w = _mm256_slli_epi32::<16>(_mm256_cvtepu16_epi32(h));
            _mm256_storeu_ps(dst.add(i), _mm256_castsi256_ps(w));
            i += 8;
        }
        while i < n {
            let h = u16::from_le_bytes([*src.add(2 * i), *src.add(2 * i + 1)]);
            *dst.add(i) = crate::math::scalar::bf16_to_f32(h);
            i += 1;
        }
        out.set_len(start + n);
    }
}

#[cfg(target_arch = "x86_64")]
pub mod sse2 {
    use std::arch::x86_64::*;

    /// SSE2 is part of the x86_64 baseline — always available.
    pub fn available() -> bool {
        true
    }

    const LANES: usize = 4;
    const DLANES: usize = 2;

    #[inline(always)]
    unsafe fn loadf(p: *const f32) -> __m128 {
        _mm_loadu_ps(p)
    }
    #[inline(always)]
    unsafe fn storef(p: *mut f32, v: __m128) {
        _mm_storeu_ps(p, v)
    }
    #[inline(always)]
    unsafe fn splatf(a: f32) -> __m128 {
        _mm_set1_ps(a)
    }
    #[inline(always)]
    unsafe fn vadd(a: __m128, b: __m128) -> __m128 {
        _mm_add_ps(a, b)
    }
    #[inline(always)]
    unsafe fn vsub(a: __m128, b: __m128) -> __m128 {
        _mm_sub_ps(a, b)
    }
    #[inline(always)]
    unsafe fn vmul(a: __m128, b: __m128) -> __m128 {
        _mm_mul_ps(a, b)
    }

    #[inline(always)]
    unsafe fn dzero() -> __m128d {
        _mm_setzero_pd()
    }
    #[inline(always)]
    unsafe fn dadd(a: __m128d, b: __m128d) -> __m128d {
        _mm_add_pd(a, b)
    }
    #[inline(always)]
    unsafe fn dsub(a: __m128d, b: __m128d) -> __m128d {
        _mm_sub_pd(a, b)
    }
    #[inline(always)]
    unsafe fn dmul(a: __m128d, b: __m128d) -> __m128d {
        _mm_mul_pd(a, b)
    }
    /// 8 consecutive f32 → four f64×2 groups, order-preserving.
    #[inline(always)]
    unsafe fn dload8(p: *const f32) -> [__m128d; 4] {
        let lo = _mm_loadu_ps(p);
        let hi = _mm_loadu_ps(p.add(4));
        [
            _mm_cvtps_pd(lo),
            _mm_cvtps_pd(_mm_movehl_ps(lo, lo)),
            _mm_cvtps_pd(hi),
            _mm_cvtps_pd(_mm_movehl_ps(hi, hi)),
        ]
    }
    #[inline(always)]
    unsafe fn dstore8(out: &mut [f64; 8], acc: [__m128d; 4]) {
        for (j, a) in acc.iter().enumerate() {
            _mm_storeu_pd(out.as_mut_ptr().add(2 * j), *a);
        }
    }

    isa_kernels!("sse2");

    /// bf16 encode: the baseline build already targets SSE2, so this is
    /// the scalar body (kept for dispatch-table uniformity).
    #[target_feature(enable = "sse2")]
    pub unsafe fn bf16_encode_into(out: &mut Vec<u8>, vals: &[f32]) {
        crate::math::scalar::bf16_encode_into(out, vals);
    }

    /// bf16 decode (scalar body, see [`bf16_encode_into`]).
    #[target_feature(enable = "sse2")]
    pub unsafe fn bf16_decode_into(out: &mut Vec<f32>, bytes: &[u8]) {
        crate::math::scalar::bf16_decode_into(out, bytes);
    }
}

#[cfg(target_arch = "aarch64")]
pub mod neon {
    use std::arch::aarch64::*;

    /// NEON is part of the aarch64 baseline — always available.
    pub fn available() -> bool {
        true
    }

    const LANES: usize = 4;
    const DLANES: usize = 2;

    #[inline(always)]
    unsafe fn loadf(p: *const f32) -> float32x4_t {
        vld1q_f32(p)
    }
    #[inline(always)]
    unsafe fn storef(p: *mut f32, v: float32x4_t) {
        vst1q_f32(p, v)
    }
    #[inline(always)]
    unsafe fn splatf(a: f32) -> float32x4_t {
        vdupq_n_f32(a)
    }
    #[inline(always)]
    unsafe fn vadd(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vaddq_f32(a, b)
    }
    #[inline(always)]
    unsafe fn vsub(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vsubq_f32(a, b)
    }
    #[inline(always)]
    unsafe fn vmul(a: float32x4_t, b: float32x4_t) -> float32x4_t {
        vmulq_f32(a, b)
    }

    #[inline(always)]
    unsafe fn dzero() -> float64x2_t {
        vdupq_n_f64(0.0)
    }
    #[inline(always)]
    unsafe fn dadd(a: float64x2_t, b: float64x2_t) -> float64x2_t {
        vaddq_f64(a, b)
    }
    #[inline(always)]
    unsafe fn dsub(a: float64x2_t, b: float64x2_t) -> float64x2_t {
        vsubq_f64(a, b)
    }
    #[inline(always)]
    unsafe fn dmul(a: float64x2_t, b: float64x2_t) -> float64x2_t {
        vmulq_f64(a, b)
    }
    /// 8 consecutive f32 → four f64×2 groups, order-preserving.
    #[inline(always)]
    unsafe fn dload8(p: *const f32) -> [float64x2_t; 4] {
        let lo = vld1q_f32(p);
        let hi = vld1q_f32(p.add(4));
        [
            vcvt_f64_f32(vget_low_f32(lo)),
            vcvt_f64_f32(vget_high_f32(lo)),
            vcvt_f64_f32(vget_low_f32(hi)),
            vcvt_f64_f32(vget_high_f32(hi)),
        ]
    }
    #[inline(always)]
    unsafe fn dstore8(out: &mut [f64; 8], acc: [float64x2_t; 4]) {
        for (j, a) in acc.iter().enumerate() {
            vst1q_f64(out.as_mut_ptr().add(2 * j), *a);
        }
    }

    isa_kernels!("neon");

    /// bf16 encode, 4 lanes per iteration (integer NEON; the scalar
    /// RNE add and quiet-NaN forcing per lane, narrowed and stored LE).
    #[target_feature(enable = "neon")]
    pub unsafe fn bf16_encode_into(out: &mut Vec<u8>, vals: &[f32]) {
        let n = vals.len();
        let start = out.len();
        out.reserve(2 * n);
        let dst = out.as_mut_ptr().add(start);
        let src = vals.as_ptr();
        let round = vdupq_n_u32(0x7fff);
        let one = vdupq_n_u32(1);
        let expmask = vdupq_n_u32(0x7f80_0000);
        let manmask = vdupq_n_u32(0x007f_ffff);
        let quiet = vdupq_n_u32(0x40);
        let mut i = 0;
        while i + 4 <= n {
            let b = vreinterpretq_u32_f32(vld1q_f32(src.add(i)));
            let lsb = vandq_u32(vshrq_n_u32::<16>(b), one);
            let r = vaddq_u32(b, vaddq_u32(round, lsb));
            let q = vshrq_n_u32::<16>(r);
            let isexp = vceqq_u32(vandq_u32(b, expmask), expmask);
            let manz = vceqq_u32(vandq_u32(b, manmask), vdupq_n_u32(0));
            let nan = vbicq_u32(isexp, manz);
            let nanres = vorrq_u32(vshrq_n_u32::<16>(b), quiet);
            let res = vbslq_u32(nan, nanres, q);
            let h = vmovn_u32(res);
            let mut lanes = [0u16; 4];
            vst1_u16(lanes.as_mut_ptr(), h);
            // byte copy: the Vec<u8> destination has no u16 alignment
            std::ptr::copy_nonoverlapping(lanes.as_ptr() as *const u8, dst.add(2 * i), 8);
            i += 4;
        }
        while i < n {
            let h = crate::math::scalar::f32_to_bf16(*src.add(i)).to_le_bytes();
            *dst.add(2 * i) = h[0];
            *dst.add(2 * i + 1) = h[1];
            i += 1;
        }
        out.set_len(start + 2 * n);
    }

    /// bf16 decode, 4 lanes per iteration: widen u16→u32, shift left 16.
    #[target_feature(enable = "neon")]
    pub unsafe fn bf16_decode_into(out: &mut Vec<f32>, bytes: &[u8]) {
        debug_assert_eq!(bytes.len() % 2, 0);
        let n = bytes.len() / 2;
        let start = out.len();
        out.reserve(n);
        let dst = out.as_mut_ptr().add(start);
        let src = bytes.as_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let mut lanes = [0u16; 4];
            // byte copy: the source byte stream has no u16 alignment
            std::ptr::copy_nonoverlapping(src.add(2 * i), lanes.as_mut_ptr() as *mut u8, 8);
            let w = vshlq_n_u32::<16>(vmovl_u16(vld1_u16(lanes.as_ptr())));
            vst1q_f32(dst.add(i), vreinterpretq_f32_u32(w));
            i += 4;
        }
        while i < n {
            let h = u16::from_le_bytes([*src.add(2 * i), *src.add(2 * i + 1)]);
            *dst.add(i) = crate::math::scalar::bf16_to_f32(h);
            i += 1;
        }
        out.set_len(start + n);
    }
}

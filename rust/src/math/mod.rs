//! Flat f32 vector kernels — the L3 request-path hot loops.
//!
//! Every master-side update rule in `optim/` is a composition of these
//! single-pass fused loops over `f32[k]` state.  They are written as
//! straight slice iterations (bounds-check-free via `zip`) so LLVM
//! auto-vectorizes them; the perf pass (EXPERIMENTS.md §Perf) measures them
//! against the memory-bandwidth roofline, and `benches/optimizer.rs` tracks
//! regressions.  The fused DANA step mirrors the L1 Pallas kernel
//! `python/compile/kernels/update.py` one-to-one.

/// y += a * x
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (y, x) in y.iter_mut().zip(x) {
        *y += a * *x;
    }
}

/// y = x (memcpy wrapper for symmetry).
pub fn copy(y: &mut [f32], x: &[f32]) {
    y.copy_from_slice(x);
}

/// x *= a
pub fn scale(x: &mut [f32], a: f32) {
    for x in x.iter_mut() {
        *x *= a;
    }
}

/// out = a - b
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert!(out.len() == a.len() && a.len() == b.len());
    for ((o, a), b) in out.iter_mut().zip(a).zip(b) {
        *o = a - b;
    }
}

/// dot(a, b) with f64 accumulation (4-way unrolled: a single f64
/// accumulator serializes the loop on its ~4-cycle add latency; four
/// independent partials let the FMA pipes overlap — see §Perf).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 4];
    let (ac, ar) = a.split_at(a.len() & !3);
    let (bc, br) = b.split_at(b.len() & !3);
    for (ca, cb) in ac.chunks_exact(4).zip(bc.chunks_exact(4)) {
        for i in 0..4 {
            acc[i] += ca[i] as f64 * cb[i] as f64;
        }
    }
    let mut tail = 0.0;
    for (&x, &y) in ar.iter().zip(br) {
        tail += x as f64 * y as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// ||a||_2^2 in f64 (4-way unrolled, see [`dot`]).
pub fn norm2_sq(a: &[f32]) -> f64 {
    let mut acc = [0.0f64; 4];
    let (chunks, rest) = a.split_at(a.len() & !3);
    for c in chunks.chunks_exact(4) {
        for i in 0..4 {
            acc[i] += c[i] as f64 * c[i] as f64;
        }
    }
    let mut tail = 0.0;
    for &x in rest {
        tail += x as f64 * x as f64;
    }
    acc[0] + acc[1] + acc[2] + acc[3] + tail
}

/// ||a - b||_2^2 without materializing the difference (8-way unrolled,
/// see [`dot`]).  Additive across contiguous shards: the sharded server
/// reduces per-shard partials with `+` before the final sqrt.
pub fn sub_norm_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; 8];
    let (ac, ar) = a.split_at(a.len() & !7);
    let (bc, br) = b.split_at(b.len() & !7);
    for (ca, cb) in ac.chunks_exact(8).zip(bc.chunks_exact(8)) {
        for i in 0..8 {
            let d = ca[i] as f64 - cb[i] as f64;
            acc[i] += d * d;
        }
    }
    let mut tail = 0.0;
    for (&x, &y) in ar.iter().zip(br) {
        let d = x as f64 - y as f64;
        tail += d * d;
    }
    acc.iter().sum::<f64>() + tail
}

/// ||a - b||_2 without materializing the difference (gap hot path).
pub fn sub_norm(a: &[f32], b: &[f32]) -> f64 {
    sub_norm_sq(a, b).sqrt()
}

/// Momentum accumulate + SGD apply in one pass (Eq 2):
/// `v = gamma*v + g; theta -= eta*v`.
pub fn momentum_step(theta: &mut [f32], v: &mut [f32], g: &[f32], gamma: f32, eta: f32) {
    debug_assert!(theta.len() == v.len() && v.len() == g.len());
    for ((t, v), g) in theta.iter_mut().zip(v.iter_mut()).zip(g) {
        let vn = gamma * *v + *g;
        *v = vn;
        *t -= eta * vn;
    }
}

/// Fused DANA-Zero master step (paper Eq 10/11 + Appendix A.2), mirroring
/// the L1 kernel `momentum_lookahead_update`:
///
/// ```text
/// v'    = gamma*v + g
/// theta'= theta - eta*v'
/// vsum' = vsum - v + v'
/// ```
/// `v`, `theta`, `vsum` update in place; one pass, each stream touched once.
pub fn dana_fused_update(
    theta: &mut [f32],
    v: &mut [f32],
    vsum: &mut [f32],
    g: &[f32],
    gamma: f32,
    eta: f32,
) {
    debug_assert!(theta.len() == v.len() && v.len() == vsum.len() && vsum.len() == g.len());
    for (((t, v), vs), g) in theta
        .iter_mut()
        .zip(v.iter_mut())
        .zip(vsum.iter_mut())
        .zip(g)
    {
        let v_new = gamma * *v + *g;
        *t -= eta * v_new;
        *vs += v_new - *v;
        *v = v_new;
    }
}

/// DANA look-ahead send (Eq 11): `hat = theta - eta*gamma*vsum`.
pub fn lookahead(hat: &mut [f32], theta: &[f32], vsum: &[f32], gamma: f32, eta: f32) {
    debug_assert!(hat.len() == theta.len() && theta.len() == vsum.len());
    let c = eta * gamma;
    for ((h, t), vs) in hat.iter_mut().zip(theta).zip(vsum) {
        *h = t - c * vs;
    }
}

/// DANA look-ahead extrapolated `depth` *extra* momentum-only steps
/// (pipelined workers): starting from (θ, v⁰), apply `depth` gradient-free
/// momentum steps `v ← γv; θ ← θ − ηv`, then the usual Eq 11 look-ahead at
/// the extrapolated point.  `depth = 0` performs exactly the operations of
/// [`lookahead`] (bit-for-bit — the pipelined driver at `--pipeline-depth
/// 0` must reproduce the synchronous trajectory exactly), and `depth = D`
/// is bit-for-bit `D` literal momentum-only applications followed by the
/// plain look-ahead, which `rust/tests/pipeline.rs` pins per coordinate.
pub fn lookahead_extrapolated(
    hat: &mut [f32],
    theta: &[f32],
    vsum: &[f32],
    gamma: f32,
    eta: f32,
    depth: usize,
) {
    debug_assert!(hat.len() == theta.len() && theta.len() == vsum.len());
    let c = eta * gamma;
    for ((h, &t0), &v0) in hat.iter_mut().zip(theta).zip(vsum) {
        let mut t = t0;
        let mut v = v0;
        for _ in 0..depth {
            v = gamma * v;
            t -= eta * v;
        }
        *h = t - c * v;
    }
}

/// Momentum-only position extrapolation: where θ lands after `depth`
/// gradient-free steps of `v ← γv; θ ← θ − ηv` — the future position a
/// shared-momentum rule (NAG-ASGD) sends to a worker whose gradient will
/// settle `depth` of its own steps in the future.  `depth = 0` copies θ.
pub fn extrapolate_position(
    out: &mut [f32],
    theta: &[f32],
    v: &[f32],
    gamma: f32,
    eta: f32,
    depth: usize,
) {
    debug_assert!(out.len() == theta.len() && theta.len() == v.len());
    for ((o, &t0), &v0) in out.iter_mut().zip(theta).zip(v) {
        let mut t = t0;
        let mut vv = v0;
        for _ in 0..depth {
            vv = gamma * vv;
            t -= eta * vv;
        }
        *o = t;
    }
}

/// DC-ASGD gradient adjustment (Eq 17):
/// `g_hat = g + lambda * g⊙g⊙(theta_master - theta_sent)`, in place on `g`.
pub fn dc_adjust(g: &mut [f32], theta_master: &[f32], theta_sent: &[f32], lambda: f32) {
    debug_assert!(g.len() == theta_master.len() && g.len() == theta_sent.len());
    for ((g, &tm), &ts) in g.iter_mut().zip(theta_master).zip(theta_sent) {
        *g += lambda * *g * *g * (tm - ts);
    }
}

/// DC-ASGD fused apply (Alg 10 lines 2–4 in one pass): compensate the
/// gradient toward the master's position, then momentum-update and apply —
/// touching each of the four streams once instead of three passes + a copy.
pub fn dc_momentum_step(
    theta: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    sent: &[f32],
    gamma: f32,
    eta: f32,
    lambda: f32,
) {
    debug_assert!(theta.len() == v.len() && v.len() == g.len() && g.len() == sent.len());
    for (((t, v), &g), &s) in theta.iter_mut().zip(v.iter_mut()).zip(g).zip(sent) {
        let ghat = g + lambda * g * g * (*t - s);
        let vn = gamma * *v + ghat;
        *v = vn;
        *t -= eta * vn;
    }
}

/// DANA-DC fused apply (Alg 7 in one pass): delay compensation + per-worker
/// momentum + master update + incremental v⁰ maintenance.
#[allow(clippy::too_many_arguments)]
pub fn dc_dana_fused_update(
    theta: &mut [f32],
    v: &mut [f32],
    vsum: &mut [f32],
    g: &[f32],
    sent: &[f32],
    gamma: f32,
    eta: f32,
    lambda: f32,
) {
    debug_assert!(
        theta.len() == v.len()
            && v.len() == vsum.len()
            && vsum.len() == g.len()
            && g.len() == sent.len()
    );
    for ((((t, v), vs), &g), &s) in theta
        .iter_mut()
        .zip(v.iter_mut())
        .zip(vsum.iter_mut())
        .zip(g)
        .zip(sent)
    {
        let ghat = g + lambda * g * g * (*t - s);
        let v_new = gamma * *v + ghat;
        *t -= eta * v_new;
        *vs += v_new - *v;
        *v = v_new;
    }
}

/// Bengio-NAG / DANA-Slim worker update vector (Alg 6 send):
/// `v = gamma*v + g` then the *sent* vector is `gamma*v + g`
/// evaluated with the *new* v, i.e. `send = gamma*v_new + g`.
/// Computes v in place and writes the send vector.
pub fn slim_worker_update(send: &mut [f32], v: &mut [f32], g: &[f32], gamma: f32) {
    debug_assert!(send.len() == v.len() && v.len() == g.len());
    for ((s, v), g) in send.iter_mut().zip(v.iter_mut()).zip(g) {
        let v_new = gamma * *v + *g;
        *v = v_new;
        *s = gamma * v_new + *g;
    }
}

/// In-place variant of [`slim_worker_update`]: the gradient buffer becomes
/// the send vector (`g[i]` is read before it is overwritten, so the
/// arithmetic is bit-identical to the scratch-buffer version).  This is the
/// per-step hot path of the DANA-Slim worker — no allocation.
pub fn slim_worker_update_inplace(v: &mut [f32], g: &mut [f32], gamma: f32) {
    debug_assert_eq!(v.len(), g.len());
    for (v, g) in v.iter_mut().zip(g.iter_mut()) {
        let v_new = gamma * *v + *g;
        *v = v_new;
        *g = gamma * v_new + *g;
    }
}

/// theta -= eta * u  (plain ASGD master apply).
pub fn apply_update(theta: &mut [f32], u: &[f32], eta: f32) {
    axpy(theta, -eta, u);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(n: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..n).map(f).collect()
    }

    #[test]
    fn axpy_basic() {
        let mut y = v(5, |i| i as f32);
        axpy(&mut y, 2.0, &v(5, |_| 1.0));
        assert_eq!(y, v(5, |i| i as f32 + 2.0));
    }

    #[test]
    fn dot_and_norms() {
        let a = [3.0f32, 4.0];
        assert_eq!(dot(&a, &a), 25.0);
        assert_eq!(norm2_sq(&a), 25.0);
        assert_eq!(sub_norm(&a, &[0.0, 0.0]), 5.0);
    }

    #[test]
    fn momentum_step_matches_equations() {
        // One step of Eq 2 by hand.
        let mut theta = [1.0f32, 2.0];
        let mut vel = [0.5f32, -0.5];
        momentum_step(&mut theta, &mut vel, &[0.1, 0.2], 0.9, 0.1);
        assert!((vel[0] - (0.9 * 0.5 + 0.1)).abs() < 1e-7);
        assert!((theta[0] - (1.0 - 0.1 * vel[0])).abs() < 1e-7);
    }

    #[test]
    fn dana_fused_matches_sequential_reference() {
        let k = 257;
        let g = v(k, |i| (i as f32 * 0.37).sin());
        let mut theta = v(k, |i| i as f32 * 0.01);
        let mut vel = v(k, |i| (i as f32 * 0.11).cos());
        let mut vsum = v(k, |i| (i as f32 * 0.05).sin() * 2.0);
        let (t0, v0, s0) = (theta.clone(), vel.clone(), vsum.clone());
        dana_fused_update(&mut theta, &mut vel, &mut vsum, &g, 0.9, 0.05);
        for i in 0..k {
            let v_new = 0.9 * v0[i] + g[i];
            assert!((vel[i] - v_new).abs() < 1e-6);
            assert!((theta[i] - (t0[i] - 0.05 * v_new)).abs() < 1e-6);
            assert!((vsum[i] - (s0[i] - v0[i] + v_new)).abs() < 1e-6);
        }
    }

    #[test]
    fn lookahead_is_eq11() {
        let theta = [1.0f32, 2.0];
        let vsum = [10.0f32, -10.0];
        let mut hat = [0.0f32; 2];
        lookahead(&mut hat, &theta, &vsum, 0.9, 0.1);
        assert!((hat[0] - (1.0 - 0.09 * 10.0)).abs() < 1e-7);
        assert!((hat[1] - (2.0 + 0.09 * 10.0)).abs() < 1e-7);
    }

    #[test]
    fn dc_adjust_is_eq17() {
        let mut g = [2.0f32];
        dc_adjust(&mut g, &[5.0], &[3.0], 0.5);
        // g + 0.5 * 4 * 2 = 2 + 4
        assert!((g[0] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn slim_inplace_matches_scratch_version() {
        let k = 65;
        let g0 = v(k, |i| (i as f32 * 0.31).sin());
        let v0 = v(k, |i| (i as f32 * 0.17).cos());
        let (mut va, mut send) = (v0.clone(), vec![0.0f32; k]);
        slim_worker_update(&mut send, &mut va, &g0, 0.9);
        let (mut vb, mut gb) = (v0.clone(), g0.clone());
        slim_worker_update_inplace(&mut vb, &mut gb, 0.9);
        assert_eq!(va, vb);
        assert_eq!(send, gb);
    }

    #[test]
    fn slim_send_vector() {
        let mut vel = [1.0f32];
        let mut send = [0.0f32];
        slim_worker_update(&mut send, &mut vel, &[0.5], 0.8);
        // v_new = 0.8 + 0.5 = 1.3 ; send = 0.8*1.3 + 0.5 = 1.54
        assert!((vel[0] - 1.3).abs() < 1e-7);
        assert!((send[0] - 1.54).abs() < 1e-6);
    }

    #[test]
    fn fused_dc_paths_match_unfused_composition() {
        let k = 131;
        let g = v(k, |i| (i as f32 * 0.21).sin() * 0.1);
        let sent = v(k, |i| i as f32 * 0.01 - 0.5);
        let (gamma, eta, lambda) = (0.9f32, 0.05f32, 1.5f32);
        // reference: dc_adjust then momentum_step / dana_fused_update
        let mut t1 = v(k, |i| (i as f32 * 0.13).cos());
        let mut v1 = v(k, |i| (i as f32 * 0.07).sin());
        let mut ghat = g.clone();
        dc_adjust(&mut ghat, &t1, &sent, lambda);
        let mut t1b = t1.clone();
        let mut v1b = v1.clone();
        momentum_step(&mut t1b, &mut v1b, &ghat, gamma, eta);
        // fused
        dc_momentum_step(&mut t1, &mut v1, &g, &sent, gamma, eta, lambda);
        for i in 0..k {
            assert!((t1[i] - t1b[i]).abs() < 1e-6);
            assert!((v1[i] - v1b[i]).abs() < 1e-6);
        }
        // DANA-DC variant
        let mut t2 = v(k, |i| (i as f32 * 0.13).cos());
        let mut v2 = v(k, |i| (i as f32 * 0.07).sin());
        let mut s2 = v(k, |i| (i as f32 * 0.03).cos());
        let mut ghat2 = g.clone();
        dc_adjust(&mut ghat2, &t2, &sent, lambda);
        let (mut t2b, mut v2b, mut s2b) = (t2.clone(), v2.clone(), s2.clone());
        dana_fused_update(&mut t2b, &mut v2b, &mut s2b, &ghat2, gamma, eta);
        dc_dana_fused_update(&mut t2, &mut v2, &mut s2, &g, &sent, gamma, eta, lambda);
        for i in 0..k {
            assert!((t2[i] - t2b[i]).abs() < 1e-6);
            assert!((v2[i] - v2b[i]).abs() < 1e-6);
            assert!((s2[i] - s2b[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn unrolled_reductions_match_naive() {
        // odd length exercises the tail path
        let a = v(1027, |i| (i as f32 * 0.37).sin());
        let b = v(1027, |i| (i as f32 * 0.11).cos());
        let naive_dot: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert!((dot(&a, &b) - naive_dot).abs() < 1e-9 * (1.0 + naive_dot.abs()));
        let naive_n2: f64 = a.iter().map(|&x| x as f64 * x as f64).sum();
        assert!((norm2_sq(&a) - naive_n2).abs() < 1e-9 * naive_n2);
        let naive_sn: f64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| (x as f64 - y as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!((sub_norm(&a, &b) - naive_sn).abs() < 1e-9 * (1.0 + naive_sn));
    }

    #[test]
    fn sub_norm_sq_is_additive_over_shards() {
        let a = v(101, |i| (i as f32 * 0.37).sin());
        let b = v(101, |i| (i as f32 * 0.11).cos());
        let whole = sub_norm_sq(&a, &b);
        let split = sub_norm_sq(&a[..40], &b[..40]) + sub_norm_sq(&a[40..], &b[40..]);
        assert!((whole - split).abs() < 1e-12 * (1.0 + whole));
        assert!((sub_norm(&a, &b) - whole.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn extrapolated_lookahead_depth_zero_is_plain_lookahead() {
        let k = 67;
        let theta = v(k, |i| (i as f32 * 0.13).cos());
        let vsum = v(k, |i| (i as f32 * 0.29).sin() * 3.0);
        let mut a = vec![0.0f32; k];
        let mut b = vec![0.0f32; k];
        lookahead(&mut a, &theta, &vsum, 0.9, 0.05);
        lookahead_extrapolated(&mut b, &theta, &vsum, 0.9, 0.05, 0);
        assert_eq!(a, b, "depth 0 must be bit-for-bit the plain look-ahead");
        extrapolate_position(&mut b, &theta, &vsum, 0.9, 0.05, 0);
        assert_eq!(b, theta, "depth 0 extrapolation is the identity");
    }

    #[test]
    fn extrapolated_lookahead_equals_literal_momentum_applications() {
        // depth D ≡ D gradient-free momentum steps then the plain
        // look-ahead, exactly (the same per-coordinate op sequence).
        let k = 41;
        let (gamma, eta) = (0.9f32, 0.05f32);
        for depth in [1usize, 2, 5] {
            let theta0 = v(k, |i| (i as f32 * 0.17).sin());
            let vsum0 = v(k, |i| (i as f32 * 0.23).cos() * 2.0);
            let (mut t, mut vs) = (theta0.clone(), vsum0.clone());
            for _ in 0..depth {
                for (ti, vi) in t.iter_mut().zip(vs.iter_mut()) {
                    *vi = gamma * *vi;
                    *ti -= eta * *vi;
                }
            }
            let mut want = vec![0.0f32; k];
            lookahead(&mut want, &t, &vs, gamma, eta);
            let mut got = vec![0.0f32; k];
            lookahead_extrapolated(&mut got, &theta0, &vsum0, gamma, eta, depth);
            assert_eq!(got, want, "depth {depth}");
            let mut pos = vec![0.0f32; k];
            extrapolate_position(&mut pos, &theta0, &vsum0, gamma, eta, depth);
            assert_eq!(pos, t, "depth {depth}: position");
        }
    }

    #[test]
    fn zero_gamma_momentum_is_sgd() {
        let mut theta = [1.0f32];
        let mut vel = [99.0f32];
        momentum_step(&mut theta, &mut vel, &[2.0], 0.0, 0.5);
        assert_eq!(vel[0], 2.0);
        assert_eq!(theta[0], 0.0);
    }
}

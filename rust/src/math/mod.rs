//! Flat f32 vector kernels — the L3 request-path hot loops, behind a
//! runtime-dispatched backend.
//!
//! Every master-side update rule in `optim/` is a composition of these
//! single-pass fused loops over `f32[k]`.  The module is split three ways
//! (DESIGN.md §15):
//!
//! * [`scalar`] — the portable reference implementation.  Defines the
//!   semantics; every other backend must match it **bit-for-bit**.
//! * [`simd`] — explicit AVX2/SSE2 (x86_64) and NEON (aarch64) kernels,
//!   written without FMA or re-association so each lane computes exactly
//!   the scalar expression.  Reductions share one fixed 8-lane
//!   strided-accumulation shape with scalar, so `dot`/`norm2_sq`/
//!   `sub_norm_sq` are deterministic across backends too.
//! * this file — the [`KernelBackend`] dispatch: detected once
//!   (`is_x86_feature_detected!`), selectable end-to-end (`--kernels
//!   auto|scalar|sse2|avx2|neon`, JSON `"kernels"`, manifest `kernels`,
//!   `DANA_KERNELS` env) and observable (`/status` + `/metrics` report
//!   [`active_kernels`]).
//!
//! The bit-for-bit contract means `--kernels scalar` is a pure
//! performance switch: goldens, equivalence suites and wire tests pass
//! identically under every backend (`rust/tests/kernels.rs` enforces
//! this exhaustively, including NaN payloads, signed zeros, infinities
//! and subnormals at every remainder length).  The fused DANA step
//! mirrors the L1 Pallas kernel `python/compile/kernels/update.py`
//! one-to-one; `benches/server.rs` (`kernels/` group) tracks the
//! scalar-vs-SIMD ratio.

pub mod scalar;
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub mod simd;

use std::sync::atomic::{AtomicU8, Ordering};

/// Fixed stride of every reduction (re-exported from [`scalar`]): 8
/// independent f64 partials, a sequential tail, a left-to-right fold.
pub use scalar::REDUCE_LANES;

// ---------------------------------------------------------- dispatch

/// One concrete kernel implementation set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum KernelBackend {
    /// Portable scalar reference (always available).
    Scalar = 1,
    /// 4-lane SSE2 (x86_64 baseline).
    Sse2 = 2,
    /// 8-lane AVX2 (x86_64, runtime-detected).
    Avx2 = 3,
    /// 4-lane NEON (aarch64 baseline).
    Neon = 4,
}

impl KernelBackend {
    fn from_u8(v: u8) -> Option<KernelBackend> {
        match v {
            1 => Some(KernelBackend::Scalar),
            2 => Some(KernelBackend::Sse2),
            3 => Some(KernelBackend::Avx2),
            4 => Some(KernelBackend::Neon),
            _ => None,
        }
    }

    /// Stable lower-case name (flag value, `/status` field, metric label).
    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Scalar => "scalar",
            KernelBackend::Sse2 => "sse2",
            KernelBackend::Avx2 => "avx2",
            KernelBackend::Neon => "neon",
        }
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What `--kernels` / `"kernels"` / `DANA_KERNELS` accept: `auto`
/// (detect the widest available backend) or one pinned backend, which
/// **fails closed** at startup when the host cannot run it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelChoice {
    #[default]
    Auto,
    Fixed(KernelBackend),
}

impl std::fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            KernelChoice::Auto => f.write_str("auto"),
            KernelChoice::Fixed(b) => f.write_str(b.name()),
        }
    }
}

impl std::str::FromStr for KernelChoice {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelChoice::Auto),
            "scalar" => Ok(KernelChoice::Fixed(KernelBackend::Scalar)),
            "sse2" => Ok(KernelChoice::Fixed(KernelBackend::Sse2)),
            "avx2" => Ok(KernelChoice::Fixed(KernelBackend::Avx2)),
            "neon" => Ok(KernelChoice::Fixed(KernelBackend::Neon)),
            other => anyhow::bail!("unknown kernel backend {other:?} (auto|scalar|sse2|avx2|neon)"),
        }
    }
}

/// Every backend this host can actually run, widest last.
pub fn available_backends() -> Vec<KernelBackend> {
    #[allow(unused_mut)] // non-SIMD arches keep just the scalar entry
    let mut v = vec![KernelBackend::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        v.push(KernelBackend::Sse2);
        if simd::avx2::available() {
            v.push(KernelBackend::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        v.push(KernelBackend::Neon);
    }
    v
}

/// Resolve a choice against this host: `auto` picks the widest
/// available backend; a pinned backend errors when unavailable.
fn resolve(choice: KernelChoice) -> anyhow::Result<KernelBackend> {
    let avail = available_backends();
    match choice {
        KernelChoice::Auto => Ok(*avail.last().expect("scalar is always available")),
        KernelChoice::Fixed(b) => {
            anyhow::ensure!(
                avail.contains(&b),
                "kernel backend {b} is not available on this host (available: {})",
                avail.iter().map(|b| b.name()).collect::<Vec<_>>().join(", ")
            );
            Ok(b)
        }
    }
}

/// The process-wide active backend.  0 = not yet initialized; first use
/// resolves `DANA_KERNELS` (or `auto`) lazily so tests and tools that
/// never touch a CLI still dispatch correctly.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// Pin the process's kernel backend (the `--kernels` flag lands here
/// before any serving/training starts).  Fails closed on a backend this
/// host cannot run; returns what was selected so callers can log it.
pub fn set_kernels(choice: KernelChoice) -> anyhow::Result<KernelBackend> {
    let b = resolve(choice)?;
    ACTIVE.store(b as u8, Ordering::SeqCst);
    Ok(b)
}

/// The backend every `math::` call currently dispatches to.
pub fn active_kernels() -> KernelBackend {
    match KernelBackend::from_u8(ACTIVE.load(Ordering::Relaxed)) {
        Some(b) => b,
        None => init_from_env(),
    }
}

#[cold]
fn init_from_env() -> KernelBackend {
    let choice = match std::env::var("DANA_KERNELS") {
        Ok(s) => s
            .parse::<KernelChoice>()
            .unwrap_or_else(|e| panic!("DANA_KERNELS: {e}")),
        Err(_) => KernelChoice::Auto,
    };
    let b = resolve(choice).unwrap_or_else(|e| panic!("DANA_KERNELS: {e}"));
    // a concurrent first-use resolves the same value, so the race is benign
    ACTIVE.store(b as u8, Ordering::SeqCst);
    b
}

/// Run `f` with the backend forced to `b`, restoring the previous
/// backend afterwards (panic-safe) — the equivalence suite's harness.
/// Serialized internally: concurrent `with_backend` calls cannot observe
/// each other's forced backend.  Panics if `b` cannot run here; gate
/// with [`available_backends`].
pub fn with_backend<R>(b: KernelBackend, f: impl FnOnce() -> R) -> R {
    static GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());
    struct Restore(u8);
    impl Drop for Restore {
        fn drop(&mut self) {
            ACTIVE.store(self.0, Ordering::SeqCst);
        }
    }
    assert!(
        available_backends().contains(&b),
        "kernel backend {b} is not available on this host"
    );
    let _g = GUARD.lock().unwrap_or_else(|p| p.into_inner());
    let restore = Restore(active_kernels() as u8);
    ACTIVE.store(b as u8, Ordering::SeqCst);
    let out = f();
    drop(restore);
    out
}

/// Routes one kernel call to the active backend.  The SIMD arms are
/// unsafe calls into `#[target_feature]` functions; the safety argument
/// is identical everywhere, so it lives here once.
macro_rules! dispatch {
    ($name:ident ( $($arg:expr),* )) => {
        match active_kernels() {
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx2 => {
                // SAFETY: Avx2 only becomes the active backend after
                // `is_x86_feature_detected!("avx2")` succeeded in resolve().
                unsafe { simd::avx2::$name($($arg),*) }
            }
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Sse2 => {
                // SAFETY: SSE2 is unconditionally part of the x86_64 baseline.
                unsafe { simd::sse2::$name($($arg),*) }
            }
            #[cfg(target_arch = "aarch64")]
            KernelBackend::Neon => {
                // SAFETY: NEON is unconditionally part of the aarch64 baseline.
                unsafe { simd::neon::$name($($arg),*) }
            }
            _ => scalar::$name($($arg),*),
        }
    };
}

// ---------------------------------------------------------- kernels

/// y += a * x
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    dispatch!(axpy(y, a, x))
}

/// y = x (memcpy wrapper for symmetry).
pub fn copy(y: &mut [f32], x: &[f32]) {
    scalar::copy(y, x);
}

/// x *= a
pub fn scale(x: &mut [f32], a: f32) {
    scalar::scale(x, a);
}

/// out = a - b
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    scalar::sub(out, a, b);
}

/// dot(a, b) with f64 accumulation over the fixed 8-lane stride —
/// deterministic across backends and thread counts (DESIGN.md §15).
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    dispatch!(dot(a, b))
}

/// ||a||_2^2 in f64 (fixed 8-lane stride, see [`dot`]).
pub fn norm2_sq(a: &[f32]) -> f64 {
    dispatch!(norm2_sq(a))
}

/// ||a - b||_2^2 without materializing the difference (fixed 8-lane
/// stride).  Additive across contiguous shards: the sharded server
/// reduces per-shard partials with `+` before the final sqrt.
pub fn sub_norm_sq(a: &[f32], b: &[f32]) -> f64 {
    dispatch!(sub_norm_sq(a, b))
}

/// ||a - b||_2 without materializing the difference (gap hot path).
pub fn sub_norm(a: &[f32], b: &[f32]) -> f64 {
    sub_norm_sq(a, b).sqrt()
}

/// Momentum accumulate + SGD apply in one pass (Eq 2):
/// `v = gamma*v + g; theta -= eta*v`.
pub fn momentum_step(theta: &mut [f32], v: &mut [f32], g: &[f32], gamma: f32, eta: f32) {
    dispatch!(momentum_step(theta, v, g, gamma, eta))
}

/// Fused DANA-Zero master step (paper Eq 10/11 + Appendix A.2), mirroring
/// the L1 kernel `momentum_lookahead_update`:
///
/// ```text
/// v'    = gamma*v + g
/// theta'= theta - eta*v'
/// vsum' = vsum - v + v'
/// ```
/// `v`, `theta`, `vsum` update in place; one pass, each stream touched once.
pub fn dana_fused_update(
    theta: &mut [f32],
    v: &mut [f32],
    vsum: &mut [f32],
    g: &[f32],
    gamma: f32,
    eta: f32,
) {
    dispatch!(dana_fused_update(theta, v, vsum, g, gamma, eta))
}

/// DANA look-ahead send (Eq 11): `hat = theta - eta*gamma*vsum`.
pub fn lookahead(hat: &mut [f32], theta: &[f32], vsum: &[f32], gamma: f32, eta: f32) {
    dispatch!(lookahead(hat, theta, vsum, gamma, eta))
}

/// DANA look-ahead extrapolated `depth` *extra* momentum-only steps
/// (pipelined workers): starting from (θ, v⁰), apply `depth` gradient-free
/// momentum steps `v ← γv; θ ← θ − ηv`, then the usual Eq 11 look-ahead at
/// the extrapolated point.  `depth = 0` performs exactly the operations of
/// [`lookahead`] (bit-for-bit — the pipelined driver at `--pipeline-depth
/// 0` must reproduce the synchronous trajectory exactly), and `depth = D`
/// is bit-for-bit `D` literal momentum-only applications followed by the
/// plain look-ahead, which `rust/tests/pipeline.rs` pins per coordinate.
pub fn lookahead_extrapolated(
    hat: &mut [f32],
    theta: &[f32],
    vsum: &[f32],
    gamma: f32,
    eta: f32,
    depth: usize,
) {
    dispatch!(lookahead_extrapolated(hat, theta, vsum, gamma, eta, depth))
}

/// Momentum-only position extrapolation: where θ lands after `depth`
/// gradient-free steps of `v ← γv; θ ← θ − ηv` — the future position a
/// shared-momentum rule (NAG-ASGD) sends to a worker whose gradient will
/// settle `depth` of its own steps in the future.  `depth = 0` copies θ.
pub fn extrapolate_position(
    out: &mut [f32],
    theta: &[f32],
    v: &[f32],
    gamma: f32,
    eta: f32,
    depth: usize,
) {
    scalar::extrapolate_position(out, theta, v, gamma, eta, depth);
}

/// DC-ASGD gradient adjustment (Eq 17):
/// `g_hat = g + lambda * g⊙g⊙(theta_master - theta_sent)`, in place on `g`.
pub fn dc_adjust(g: &mut [f32], theta_master: &[f32], theta_sent: &[f32], lambda: f32) {
    dispatch!(dc_adjust(g, theta_master, theta_sent, lambda))
}

/// DC-ASGD fused apply (Alg 10 lines 2–4 in one pass): compensate the
/// gradient toward the master's position, then momentum-update and apply —
/// touching each of the four streams once instead of three passes + a copy.
pub fn dc_momentum_step(
    theta: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    sent: &[f32],
    gamma: f32,
    eta: f32,
    lambda: f32,
) {
    scalar::dc_momentum_step(theta, v, g, sent, gamma, eta, lambda);
}

/// DANA-DC fused apply (Alg 7 in one pass): delay compensation + per-worker
/// momentum + master update + incremental v⁰ maintenance.
#[allow(clippy::too_many_arguments)]
pub fn dc_dana_fused_update(
    theta: &mut [f32],
    v: &mut [f32],
    vsum: &mut [f32],
    g: &[f32],
    sent: &[f32],
    gamma: f32,
    eta: f32,
    lambda: f32,
) {
    dispatch!(dc_dana_fused_update(theta, v, vsum, g, sent, gamma, eta, lambda))
}

/// Bengio-NAG / DANA-Slim worker update vector (Alg 6 send):
/// `v = gamma*v + g` then the *sent* vector is `gamma*v + g`
/// evaluated with the *new* v, i.e. `send = gamma*v_new + g`.
/// Computes v in place and writes the send vector.
pub fn slim_worker_update(send: &mut [f32], v: &mut [f32], g: &[f32], gamma: f32) {
    scalar::slim_worker_update(send, v, g, gamma);
}

/// In-place variant of [`slim_worker_update`]: the gradient buffer becomes
/// the send vector (`g[i]` is read before it is overwritten, so the
/// arithmetic is bit-identical to the scratch-buffer version).  This is the
/// per-step hot path of the DANA-Slim worker — no allocation.
pub fn slim_worker_update_inplace(v: &mut [f32], g: &mut [f32], gamma: f32) {
    dispatch!(slim_worker_update_inplace(v, g, gamma))
}

/// theta -= eta * u  (plain ASGD master apply).
pub fn apply_update(theta: &mut [f32], u: &[f32], eta: f32) {
    axpy(theta, -eta, u);
}

// ------------------------------------------------- f16/bf16 batch codecs

/// Append `vals` as little-endian IEEE binary16 bits (wire hot loop).
pub fn f16_encode_into(out: &mut Vec<u8>, vals: &[f32]) {
    dispatch!(f16_encode_into(out, vals))
}

/// Append `vals` as little-endian bfloat16 bits (wire hot loop).
pub fn bf16_encode_into(out: &mut Vec<u8>, vals: &[f32]) {
    dispatch!(bf16_encode_into(out, vals))
}

/// Decode little-endian f16 bytes, appending f32s (`bytes.len()` even).
pub fn f16_decode_into(out: &mut Vec<f32>, bytes: &[u8]) {
    dispatch!(f16_decode_into(out, bytes))
}

/// Decode little-endian bf16 bytes, appending f32s (`bytes.len()` even).
pub fn bf16_decode_into(out: &mut Vec<f32>, bytes: &[u8]) {
    dispatch!(bf16_decode_into(out, bytes))
}

/// Quantize–dequantize through f16 in place (compressor transform).
pub fn f16_round_trip(g: &mut [f32]) {
    dispatch!(f16_round_trip(g))
}

/// Quantize–dequantize through bf16 in place (compressor transform).
pub fn bf16_round_trip(g: &mut [f32]) {
    dispatch!(bf16_round_trip(g))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(n: usize, f: impl Fn(usize) -> f32) -> Vec<f32> {
        (0..n).map(f).collect()
    }

    #[test]
    fn axpy_basic() {
        let mut y = v(5, |i| i as f32);
        axpy(&mut y, 2.0, &v(5, |_| 1.0));
        assert_eq!(y, v(5, |i| i as f32 + 2.0));
    }

    #[test]
    fn dot_and_norms() {
        let a = [3.0f32, 4.0];
        assert_eq!(dot(&a, &a), 25.0);
        assert_eq!(norm2_sq(&a), 25.0);
        assert_eq!(sub_norm(&a, &[0.0, 0.0]), 5.0);
    }

    #[test]
    fn momentum_step_matches_equations() {
        // One step of Eq 2 by hand.
        let mut theta = [1.0f32, 2.0];
        let mut vel = [0.5f32, -0.5];
        momentum_step(&mut theta, &mut vel, &[0.1, 0.2], 0.9, 0.1);
        assert!((vel[0] - (0.9 * 0.5 + 0.1)).abs() < 1e-7);
        assert!((theta[0] - (1.0 - 0.1 * vel[0])).abs() < 1e-7);
    }

    #[test]
    fn dana_fused_matches_sequential_reference() {
        let k = 257;
        let g = v(k, |i| (i as f32 * 0.37).sin());
        let mut theta = v(k, |i| i as f32 * 0.01);
        let mut vel = v(k, |i| (i as f32 * 0.11).cos());
        let mut vsum = v(k, |i| (i as f32 * 0.05).sin() * 2.0);
        let (t0, v0, s0) = (theta.clone(), vel.clone(), vsum.clone());
        dana_fused_update(&mut theta, &mut vel, &mut vsum, &g, 0.9, 0.05);
        for i in 0..k {
            let v_new = 0.9 * v0[i] + g[i];
            assert!((vel[i] - v_new).abs() < 1e-6);
            assert!((theta[i] - (t0[i] - 0.05 * v_new)).abs() < 1e-6);
            assert!((vsum[i] - (s0[i] - v0[i] + v_new)).abs() < 1e-6);
        }
    }

    #[test]
    fn lookahead_is_eq11() {
        let theta = [1.0f32, 2.0];
        let vsum = [10.0f32, -10.0];
        let mut hat = [0.0f32; 2];
        lookahead(&mut hat, &theta, &vsum, 0.9, 0.1);
        assert!((hat[0] - (1.0 - 0.09 * 10.0)).abs() < 1e-7);
        assert!((hat[1] - (2.0 + 0.09 * 10.0)).abs() < 1e-7);
    }

    #[test]
    fn dc_adjust_is_eq17() {
        let mut g = [2.0f32];
        dc_adjust(&mut g, &[5.0], &[3.0], 0.5);
        // g + 0.5 * 4 * 2 = 2 + 4
        assert!((g[0] - 6.0).abs() < 1e-7);
    }

    #[test]
    fn slim_inplace_matches_scratch_version() {
        let k = 65;
        let g0 = v(k, |i| (i as f32 * 0.31).sin());
        let v0 = v(k, |i| (i as f32 * 0.17).cos());
        let (mut va, mut send) = (v0.clone(), vec![0.0f32; k]);
        slim_worker_update(&mut send, &mut va, &g0, 0.9);
        let (mut vb, mut gb) = (v0.clone(), g0.clone());
        slim_worker_update_inplace(&mut vb, &mut gb, 0.9);
        assert_eq!(va, vb);
        assert_eq!(send, gb);
    }

    #[test]
    fn slim_send_vector() {
        let mut vel = [1.0f32];
        let mut send = [0.0f32];
        slim_worker_update(&mut send, &mut vel, &[0.5], 0.8);
        // v_new = 0.8 + 0.5 = 1.3 ; send = 0.8*1.3 + 0.5 = 1.54
        assert!((vel[0] - 1.3).abs() < 1e-7);
        assert!((send[0] - 1.54).abs() < 1e-6);
    }

    #[test]
    fn fused_dc_paths_match_unfused_composition() {
        let k = 131;
        let g = v(k, |i| (i as f32 * 0.21).sin() * 0.1);
        let sent = v(k, |i| i as f32 * 0.01 - 0.5);
        let (gamma, eta, lambda) = (0.9f32, 0.05f32, 1.5f32);
        // reference: dc_adjust then momentum_step / dana_fused_update
        let mut t1 = v(k, |i| (i as f32 * 0.13).cos());
        let mut v1 = v(k, |i| (i as f32 * 0.07).sin());
        let mut ghat = g.clone();
        dc_adjust(&mut ghat, &t1, &sent, lambda);
        let mut t1b = t1.clone();
        let mut v1b = v1.clone();
        momentum_step(&mut t1b, &mut v1b, &ghat, gamma, eta);
        // fused
        dc_momentum_step(&mut t1, &mut v1, &g, &sent, gamma, eta, lambda);
        for i in 0..k {
            assert!((t1[i] - t1b[i]).abs() < 1e-6);
            assert!((v1[i] - v1b[i]).abs() < 1e-6);
        }
        // DANA-DC variant
        let mut t2 = v(k, |i| (i as f32 * 0.13).cos());
        let mut v2 = v(k, |i| (i as f32 * 0.07).sin());
        let mut s2 = v(k, |i| (i as f32 * 0.03).cos());
        let mut ghat2 = g.clone();
        dc_adjust(&mut ghat2, &t2, &sent, lambda);
        let (mut t2b, mut v2b, mut s2b) = (t2.clone(), v2.clone(), s2.clone());
        dana_fused_update(&mut t2b, &mut v2b, &mut s2b, &ghat2, gamma, eta);
        dc_dana_fused_update(&mut t2, &mut v2, &mut s2, &g, &sent, gamma, eta, lambda);
        for i in 0..k {
            assert!((t2[i] - t2b[i]).abs() < 1e-6);
            assert!((v2[i] - v2b[i]).abs() < 1e-6);
            assert!((s2[i] - s2b[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn unrolled_reductions_match_naive() {
        // odd length exercises the tail path
        let a = v(1027, |i| (i as f32 * 0.37).sin());
        let b = v(1027, |i| (i as f32 * 0.11).cos());
        let naive_dot: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
        assert!((dot(&a, &b) - naive_dot).abs() < 1e-9 * (1.0 + naive_dot.abs()));
        let naive_n2: f64 = a.iter().map(|&x| x as f64 * x as f64).sum();
        assert!((norm2_sq(&a) - naive_n2).abs() < 1e-9 * naive_n2);
        let naive_sn: f64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| (x as f64 - y as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!((sub_norm(&a, &b) - naive_sn).abs() < 1e-9 * (1.0 + naive_sn));
    }

    #[test]
    fn sub_norm_sq_is_additive_over_shards() {
        let a = v(101, |i| (i as f32 * 0.37).sin());
        let b = v(101, |i| (i as f32 * 0.11).cos());
        let whole = sub_norm_sq(&a, &b);
        let split = sub_norm_sq(&a[..40], &b[..40]) + sub_norm_sq(&a[40..], &b[40..]);
        assert!((whole - split).abs() < 1e-12 * (1.0 + whole));
        assert!((sub_norm(&a, &b) - whole.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn extrapolated_lookahead_depth_zero_is_plain_lookahead() {
        let k = 67;
        let theta = v(k, |i| (i as f32 * 0.13).cos());
        let vsum = v(k, |i| (i as f32 * 0.29).sin() * 3.0);
        let mut a = vec![0.0f32; k];
        let mut b = vec![0.0f32; k];
        lookahead(&mut a, &theta, &vsum, 0.9, 0.05);
        lookahead_extrapolated(&mut b, &theta, &vsum, 0.9, 0.05, 0);
        assert_eq!(a, b, "depth 0 must be bit-for-bit the plain look-ahead");
        extrapolate_position(&mut b, &theta, &vsum, 0.9, 0.05, 0);
        assert_eq!(b, theta, "depth 0 extrapolation is the identity");
    }

    #[test]
    fn extrapolated_lookahead_equals_literal_momentum_applications() {
        // depth D ≡ D gradient-free momentum steps then the plain
        // look-ahead, exactly (the same per-coordinate op sequence).
        let k = 41;
        let (gamma, eta) = (0.9f32, 0.05f32);
        for depth in [1usize, 2, 5] {
            let theta0 = v(k, |i| (i as f32 * 0.17).sin());
            let vsum0 = v(k, |i| (i as f32 * 0.23).cos() * 2.0);
            let (mut t, mut vs) = (theta0.clone(), vsum0.clone());
            for _ in 0..depth {
                for (ti, vi) in t.iter_mut().zip(vs.iter_mut()) {
                    *vi = gamma * *vi;
                    *ti -= eta * *vi;
                }
            }
            let mut want = vec![0.0f32; k];
            lookahead(&mut want, &t, &vs, gamma, eta);
            let mut got = vec![0.0f32; k];
            lookahead_extrapolated(&mut got, &theta0, &vsum0, gamma, eta, depth);
            assert_eq!(got, want, "depth {depth}");
            let mut pos = vec![0.0f32; k];
            extrapolate_position(&mut pos, &theta0, &vsum0, gamma, eta, depth);
            assert_eq!(pos, t, "depth {depth}: position");
        }
    }

    #[test]
    fn zero_gamma_momentum_is_sgd() {
        let mut theta = [1.0f32];
        let mut vel = [99.0f32];
        momentum_step(&mut theta, &mut vel, &[2.0], 0.0, 0.5);
        assert_eq!(vel[0], 2.0);
        assert_eq!(theta[0], 0.0);
    }

    #[test]
    fn backend_parse_and_display_round_trip() {
        for s in ["auto", "scalar", "sse2", "avx2", "neon"] {
            let c: KernelChoice = s.parse().unwrap();
            assert_eq!(c.to_string(), s);
        }
        assert!("avx512".parse::<KernelChoice>().is_err());
        assert_eq!(KernelChoice::default(), KernelChoice::Auto);
    }

    #[test]
    fn scalar_is_always_available_and_forcible() {
        let avail = available_backends();
        assert!(avail.contains(&KernelBackend::Scalar));
        let got = with_backend(KernelBackend::Scalar, active_kernels);
        assert_eq!(got, KernelBackend::Scalar);
        // set_kernels(auto) resolves to the widest available backend
        let auto = set_kernels(KernelChoice::Auto).unwrap();
        assert_eq!(auto, *avail.last().unwrap());
        assert_eq!(active_kernels(), auto);
    }

    #[test]
    fn pinning_an_unavailable_backend_fails_closed() {
        // at most one of neon/avx2 can exist on one host; whichever is
        // absent must be rejected by name
        for b in [KernelBackend::Neon, KernelBackend::Avx2, KernelBackend::Sse2] {
            if !available_backends().contains(&b) {
                let err = set_kernels(KernelChoice::Fixed(b)).unwrap_err().to_string();
                assert!(err.contains("not available"), "{err}");
                assert!(err.contains(b.name()), "{err}");
            }
        }
    }
}

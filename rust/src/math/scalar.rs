//! Portable scalar reference kernels — the semantics every other
//! backend must reproduce **bit-for-bit**.
//!
//! The dispatch wrappers in [`super`] (`math::axpy` etc.) route here when
//! the active [`super::KernelBackend`] is `Scalar`, and the SIMD backends
//! in [`super::simd`] are required (and property-tested in
//! `rust/tests/kernels.rs`) to produce identical bits for every input,
//! including NaN payloads, signed zeros, infinities and subnormals:
//!
//! * The elementwise kernels are pure per-coordinate IEEE-754 f32
//!   arithmetic with no re-association and no fused multiply-add, so a
//!   vector lane computes exactly the scalar expression.
//! * The reductions (`dot`, `norm2_sq`, `sub_norm_sq`) use one fixed
//!   **8-lane strided accumulation** shape (8 independent f64 partials
//!   over `chunks_exact(8)`, a sequential scalar tail, then a sequential
//!   left-to-right fold `acc[0] + acc[1] + … + tail`).  The SIMD
//!   backends implement the same shape with vertical f64 lane adds and
//!   the same final fold order, so the reduction result is deterministic
//!   across dispatch choices and thread counts (DESIGN.md §15).
//!
//! These loops are written as straight slice iterations
//! (bounds-check-free via `zip`) so LLVM auto-vectorizes the scalar
//! build too; the explicit backends exist to make the vector width a
//! contract instead of an optimizer mood.

/// Fixed stride of every reduction in this crate (see module docs).
pub const REDUCE_LANES: usize = 8;

/// y += a * x
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (y, x) in y.iter_mut().zip(x) {
        *y += a * *x;
    }
}

/// y = x (memcpy wrapper for symmetry).
pub fn copy(y: &mut [f32], x: &[f32]) {
    y.copy_from_slice(x);
}

/// x *= a
pub fn scale(x: &mut [f32], a: f32) {
    for x in x.iter_mut() {
        *x *= a;
    }
}

/// out = a - b
pub fn sub(out: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert!(out.len() == a.len() && a.len() == b.len());
    for ((o, a), b) in out.iter_mut().zip(a).zip(b) {
        *o = a - b;
    }
}

/// dot(a, b) with f64 accumulation over the fixed 8-lane stride.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; REDUCE_LANES];
    let (ac, ar) = a.split_at(a.len() & !(REDUCE_LANES - 1));
    let (bc, br) = b.split_at(b.len() & !(REDUCE_LANES - 1));
    for (ca, cb) in ac.chunks_exact(REDUCE_LANES).zip(bc.chunks_exact(REDUCE_LANES)) {
        for i in 0..REDUCE_LANES {
            acc[i] += ca[i] as f64 * cb[i] as f64;
        }
    }
    let mut tail = 0.0;
    for (&x, &y) in ar.iter().zip(br) {
        tail += x as f64 * y as f64;
    }
    fold_acc(&acc) + tail
}

/// ||a||_2^2 in f64 over the fixed 8-lane stride.
pub fn norm2_sq(a: &[f32]) -> f64 {
    let mut acc = [0.0f64; REDUCE_LANES];
    let (chunks, rest) = a.split_at(a.len() & !(REDUCE_LANES - 1));
    for c in chunks.chunks_exact(REDUCE_LANES) {
        for i in 0..REDUCE_LANES {
            acc[i] += c[i] as f64 * c[i] as f64;
        }
    }
    let mut tail = 0.0;
    for &x in rest {
        tail += x as f64 * x as f64;
    }
    fold_acc(&acc) + tail
}

/// ||a - b||_2^2 without materializing the difference.  Additive across
/// contiguous shards: the sharded server reduces per-shard partials with
/// `+` before the final sqrt.
pub fn sub_norm_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f64; REDUCE_LANES];
    let (ac, ar) = a.split_at(a.len() & !(REDUCE_LANES - 1));
    let (bc, br) = b.split_at(b.len() & !(REDUCE_LANES - 1));
    for (ca, cb) in ac.chunks_exact(REDUCE_LANES).zip(bc.chunks_exact(REDUCE_LANES)) {
        for i in 0..REDUCE_LANES {
            let d = ca[i] as f64 - cb[i] as f64;
            acc[i] += d * d;
        }
    }
    let mut tail = 0.0;
    for (&x, &y) in ar.iter().zip(br) {
        let d = x as f64 - y as f64;
        tail += d * d;
    }
    fold_acc(&acc) + tail
}

/// The one reduction fold order: a sequential left-to-right sum of the
/// 8 partials.  Every backend finishes with exactly this.
#[inline(always)]
pub fn fold_acc(acc: &[f64; REDUCE_LANES]) -> f64 {
    let mut s = 0.0;
    for &a in acc {
        s += a;
    }
    s
}

/// Momentum accumulate + SGD apply in one pass (Eq 2):
/// `v = gamma*v + g; theta -= eta*v`.
pub fn momentum_step(theta: &mut [f32], v: &mut [f32], g: &[f32], gamma: f32, eta: f32) {
    debug_assert!(theta.len() == v.len() && v.len() == g.len());
    for ((t, v), g) in theta.iter_mut().zip(v.iter_mut()).zip(g) {
        let vn = gamma * *v + *g;
        *v = vn;
        *t -= eta * vn;
    }
}

/// Fused DANA-Zero master step (paper Eq 10/11 + Appendix A.2).
pub fn dana_fused_update(
    theta: &mut [f32],
    v: &mut [f32],
    vsum: &mut [f32],
    g: &[f32],
    gamma: f32,
    eta: f32,
) {
    debug_assert!(theta.len() == v.len() && v.len() == vsum.len() && vsum.len() == g.len());
    for (((t, v), vs), g) in theta
        .iter_mut()
        .zip(v.iter_mut())
        .zip(vsum.iter_mut())
        .zip(g)
    {
        let v_new = gamma * *v + *g;
        *t -= eta * v_new;
        *vs += v_new - *v;
        *v = v_new;
    }
}

/// DANA look-ahead send (Eq 11): `hat = theta - eta*gamma*vsum`.
pub fn lookahead(hat: &mut [f32], theta: &[f32], vsum: &[f32], gamma: f32, eta: f32) {
    debug_assert!(hat.len() == theta.len() && theta.len() == vsum.len());
    let c = eta * gamma;
    for ((h, t), vs) in hat.iter_mut().zip(theta).zip(vsum) {
        *h = t - c * vs;
    }
}

/// DANA look-ahead extrapolated `depth` *extra* momentum-only steps.
pub fn lookahead_extrapolated(
    hat: &mut [f32],
    theta: &[f32],
    vsum: &[f32],
    gamma: f32,
    eta: f32,
    depth: usize,
) {
    debug_assert!(hat.len() == theta.len() && theta.len() == vsum.len());
    let c = eta * gamma;
    for ((h, &t0), &v0) in hat.iter_mut().zip(theta).zip(vsum) {
        let mut t = t0;
        let mut v = v0;
        for _ in 0..depth {
            v = gamma * v;
            t -= eta * v;
        }
        *h = t - c * v;
    }
}

/// Momentum-only position extrapolation (`depth = 0` copies θ).
pub fn extrapolate_position(
    out: &mut [f32],
    theta: &[f32],
    v: &[f32],
    gamma: f32,
    eta: f32,
    depth: usize,
) {
    debug_assert!(out.len() == theta.len() && theta.len() == v.len());
    for ((o, &t0), &v0) in out.iter_mut().zip(theta).zip(v) {
        let mut t = t0;
        let mut vv = v0;
        for _ in 0..depth {
            vv = gamma * vv;
            t -= eta * vv;
        }
        *o = t;
    }
}

/// DC-ASGD gradient adjustment (Eq 17), in place on `g`.
pub fn dc_adjust(g: &mut [f32], theta_master: &[f32], theta_sent: &[f32], lambda: f32) {
    debug_assert!(g.len() == theta_master.len() && g.len() == theta_sent.len());
    for ((g, &tm), &ts) in g.iter_mut().zip(theta_master).zip(theta_sent) {
        *g += lambda * *g * *g * (tm - ts);
    }
}

/// DC-ASGD fused apply (Alg 10 lines 2–4 in one pass).
pub fn dc_momentum_step(
    theta: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    sent: &[f32],
    gamma: f32,
    eta: f32,
    lambda: f32,
) {
    debug_assert!(theta.len() == v.len() && v.len() == g.len() && g.len() == sent.len());
    for (((t, v), &g), &s) in theta.iter_mut().zip(v.iter_mut()).zip(g).zip(sent) {
        let ghat = g + lambda * g * g * (*t - s);
        let vn = gamma * *v + ghat;
        *v = vn;
        *t -= eta * vn;
    }
}

/// DANA-DC fused apply (Alg 7 in one pass).
#[allow(clippy::too_many_arguments)]
pub fn dc_dana_fused_update(
    theta: &mut [f32],
    v: &mut [f32],
    vsum: &mut [f32],
    g: &[f32],
    sent: &[f32],
    gamma: f32,
    eta: f32,
    lambda: f32,
) {
    debug_assert!(
        theta.len() == v.len()
            && v.len() == vsum.len()
            && vsum.len() == g.len()
            && g.len() == sent.len()
    );
    for ((((t, v), vs), &g), &s) in theta
        .iter_mut()
        .zip(v.iter_mut())
        .zip(vsum.iter_mut())
        .zip(g)
        .zip(sent)
    {
        let ghat = g + lambda * g * g * (*t - s);
        let v_new = gamma * *v + ghat;
        *t -= eta * v_new;
        *vs += v_new - *v;
        *v = v_new;
    }
}

/// Bengio-NAG / DANA-Slim worker update vector (Alg 6 send).
pub fn slim_worker_update(send: &mut [f32], v: &mut [f32], g: &[f32], gamma: f32) {
    debug_assert!(send.len() == v.len() && v.len() == g.len());
    for ((s, v), g) in send.iter_mut().zip(v.iter_mut()).zip(g) {
        let v_new = gamma * *v + *g;
        *v = v_new;
        *s = gamma * v_new + *g;
    }
}

/// In-place variant of [`slim_worker_update`] (`g` becomes the send
/// vector; `g[i]` is read before it is overwritten, so the arithmetic is
/// bit-identical to the scratch-buffer version).
pub fn slim_worker_update_inplace(v: &mut [f32], g: &mut [f32], gamma: f32) {
    debug_assert_eq!(v.len(), g.len());
    for (v, g) in v.iter_mut().zip(g.iter_mut()) {
        let v_new = gamma * *v + *g;
        *v = v_new;
        *g = gamma * v_new + *g;
    }
}

/// theta -= eta * u  (plain ASGD master apply).
pub fn apply_update(theta: &mut [f32], u: &[f32], eta: f32) {
    axpy(theta, -eta, u);
}

// ------------------------------------------------- f16 / bf16 reference
//
// The per-element converters live here (re-exported by `net::codec`, the
// historical home) so the batch encode/decode kernels the wire hot path
// dispatches can share one reference definition with the SIMD backends.

/// f32 → IEEE binary16 bits, round-to-nearest-even (overflow → ±inf,
/// NaN stays NaN with a nonzero mantissa).
#[inline(always)]
pub fn f32_to_f16(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let exp = ((b >> 23) & 0xff) as i32;
    let man = b & 0x007f_ffff;
    if exp == 0xff {
        // inf / NaN: keep NaN-ness with a nonzero mantissa
        return if man == 0 { sign | 0x7c00 } else { sign | 0x7c00 | ((man >> 13) as u16).max(1) };
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        return sign | 0x7c00; // overflow → inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow → signed zero
        }
        // subnormal half: shift the full 24-bit significand down,
        // rounding to nearest-even on the dropped bits
        let m = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let kept = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded =
            if rem > halfway || (rem == halfway && kept & 1 == 1) { kept + 1 } else { kept };
        return sign | rounded as u16; // carry into exp 1 is correct
    }
    let kept = (man >> 13) as u16;
    let rem = man & 0x1fff;
    let mut h = sign | ((e as u16) << 10) | kept;
    if rem > 0x1000 || (rem == 0x1000 && h & 1 == 1) {
        h += 1; // mantissa carry may roll into the exponent (→ inf): correct
    }
    h
}

/// IEEE binary16 bits → f32 (exact — every half is representable).
#[inline(always)]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: normalize into an f32 normal
            let mut m = man;
            let mut e32 = 113u32; // f32 exponent field once bit 10 lands
            while m & 0x0400 == 0 {
                m <<= 1;
                e32 -= 1;
            }
            sign | (e32 << 23) | ((m & 0x03ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 → bfloat16 bits, round-to-nearest-even (NaN stays NaN).
#[inline(always)]
pub fn f32_to_bf16(x: f32) -> u16 {
    let b = x.to_bits();
    if x.is_nan() {
        return ((b >> 16) as u16) | 0x0040; // force a quiet, nonzero mantissa
    }
    (b.wrapping_add(0x7fff + ((b >> 16) & 1)) >> 16) as u16
}

/// bfloat16 bits → f32 (exact — bf16 is a truncated f32).
#[inline(always)]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Append `vals` as little-endian f16 bits (the `put_payload` hot loop).
#[inline(always)]
pub fn f16_encode_into(out: &mut Vec<u8>, vals: &[f32]) {
    out.reserve(2 * vals.len());
    for &x in vals {
        out.extend_from_slice(&f32_to_f16(x).to_le_bytes());
    }
}

/// Append `vals` as little-endian bf16 bits.
#[inline(always)]
pub fn bf16_encode_into(out: &mut Vec<u8>, vals: &[f32]) {
    out.reserve(2 * vals.len());
    for &x in vals {
        out.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
    }
}

/// Decode little-endian f16 bytes, appending f32s (the `get_payload`
/// densify loop; `bytes.len()` must be even).  NaN *checking* stays with
/// the fail-closed decoder in `net::codec`.
#[inline(always)]
pub fn f16_decode_into(out: &mut Vec<f32>, bytes: &[u8]) {
    debug_assert_eq!(bytes.len() % 2, 0);
    out.reserve(bytes.len() / 2);
    for c in bytes.chunks_exact(2) {
        out.push(f16_to_f32(u16::from_le_bytes([c[0], c[1]])));
    }
}

/// Decode little-endian bf16 bytes, appending f32s.
#[inline(always)]
pub fn bf16_decode_into(out: &mut Vec<f32>, bytes: &[u8]) {
    debug_assert_eq!(bytes.len() % 2, 0);
    out.reserve(bytes.len() / 2);
    for c in bytes.chunks_exact(2) {
        out.push(bf16_to_f32(u16::from_le_bytes([c[0], c[1]])));
    }
}

/// Quantize–dequantize through f16 in place (the `Compressor` transform:
/// the caller trains against exactly the values the wire will carry).
#[inline(always)]
pub fn f16_round_trip(g: &mut [f32]) {
    for x in g.iter_mut() {
        *x = f16_to_f32(f32_to_f16(*x));
    }
}

/// Quantize–dequantize through bf16 in place.
#[inline(always)]
pub fn bf16_round_trip(g: &mut [f32]) {
    for x in g.iter_mut() {
        *x = bf16_to_f32(f32_to_bf16(*x));
    }
}

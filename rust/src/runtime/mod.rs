//! PJRT runtime: load AOT artifacts and execute them from the request path.
//!
//! The bridge follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.  One [`Engine`] per thread (the
//! `xla` wrapper types hold raw pointers and are not `Send`); the real-async
//! trainer gives each worker thread its own engine, the simulated trainer
//! runs everything on the driver thread.

pub mod exec;
pub mod manifest;

pub use exec::{Input, Model, UpdateKernelExec};
pub use manifest::{Manifest, Variant};

use std::path::Path;

/// A PJRT CPU client plus the manifest it serves artifacts from.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(Engine { client, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_hlo(&self, path: &Path) -> anyhow::Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))
    }

    /// Load + compile the train/eval executables of a variant.
    pub fn load_model(&self, name: &str) -> anyhow::Result<Model> {
        let v = self.manifest.variant(name)?.clone();
        let train = self.compile_hlo(&v.train_hlo)?;
        let eval = self.compile_hlo(&v.eval_hlo)?;
        Ok(Model::new(v, train, eval))
    }

    /// Load + compile the fused DANA master-update kernel artifact
    /// (ablation: execute the L1 kernel through PJRT instead of the native
    /// rust loop).
    pub fn load_update_kernel(&self) -> anyhow::Result<UpdateKernelExec> {
        let uk = self
            .manifest
            .update_kernel
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("manifest has no update_kernel"))?
            .clone();
        let exe = self.compile_hlo(&uk.file)?;
        Ok(UpdateKernelExec::new(uk, exe))
    }

    /// Initial parameters for a variant (the python-side init, so rust and
    /// python training trajectories share a starting point).
    pub fn init_params(&self, name: &str) -> anyhow::Result<Vec<f32>> {
        let v = self.manifest.variant(name)?;
        manifest::read_f32_file(&v.init_params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn engine_loads_and_reports_platform() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let e = Engine::cpu(&dir).unwrap();
        assert_eq!(e.platform().to_lowercase(), "cpu");
        assert!(e.manifest().variants.len() >= 4);
    }

    #[test]
    fn golden_cross_check_mlp() {
        // The core integration guarantee: the rust runtime executing the
        // AOT artifact reproduces python's loss/grads on the golden batch.
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let e = Engine::cpu(&dir).unwrap();
        for name in ["mlp_c10_ref", "mlp_c10"] {
            let m = e.load_model(name).unwrap();
            let v = e.manifest().variant(name).unwrap();
            let params = e.init_params(name).unwrap();
            let gx = manifest::read_f32_file(&v.golden_x).unwrap();
            let gy = manifest::read_i32_file(&v.golden_y).unwrap();
            let (loss, grads) = m.train_step(&params, Input::F32(&gx), &gy).unwrap();
            assert!(
                (loss as f64 - v.golden.loss).abs() < 1e-4,
                "{name}: loss {loss} vs golden {}",
                v.golden.loss
            );
            let l2 = crate::util::stats::l2_norm(&grads);
            assert!(
                (l2 - v.golden.grad_l2).abs() / v.golden.grad_l2 < 1e-3,
                "{name}: grad_l2 {l2} vs {}",
                v.golden.grad_l2
            );
            for (i, &want) in v.golden.grad_prefix.iter().enumerate() {
                assert!(
                    (grads[i] as f64 - want).abs() < 1e-5 + want.abs() * 1e-3,
                    "{name}: grad[{i}] {} vs {want}",
                    grads[i]
                );
            }
            let (eloss, ecorr) = m.eval_step(&params, Input::F32(&gx), &gy).unwrap();
            assert!((eloss as f64 - v.golden.eval_loss).abs() < 1e-4);
            assert_eq!(ecorr as f64, v.golden.eval_correct);
        }
    }

    #[test]
    fn golden_cross_check_lm() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let e = Engine::cpu(&dir).unwrap();
        let name = "lm_small_ref";
        let m = e.load_model(name).unwrap();
        let v = e.manifest().variant(name).unwrap();
        let params = e.init_params(name).unwrap();
        let gx = manifest::read_i32_file(&v.golden_x).unwrap();
        let gy = manifest::read_i32_file(&v.golden_y).unwrap();
        let (loss, grads) = m.train_step(&params, Input::I32(&gx), &gy).unwrap();
        assert!((loss as f64 - v.golden.loss).abs() < 1e-4);
        let l2 = crate::util::stats::l2_norm(&grads);
        assert!((l2 - v.golden.grad_l2).abs() / v.golden.grad_l2 < 1e-3);
    }

    #[test]
    fn update_kernel_matches_native_math() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let e = Engine::cpu(&dir).unwrap();
        let uk = e.load_update_kernel().unwrap();
        let k = uk.k();
        let mut rng = crate::util::rng::Rng::new(17);
        let mk = |rng: &mut crate::util::rng::Rng| -> Vec<f32> {
            (0..k).map(|_| rng.normal() as f32).collect()
        };
        let (theta, v, vsum, g) = (mk(&mut rng), mk(&mut rng), mk(&mut rng), mk(&mut rng));
        let (t2, v2, s2, hat) = uk.apply(0.9, 0.05, &theta, &v, &vsum, &g).unwrap();
        // native fused loop
        let (mut tn, mut vn, mut sn) = (theta.clone(), v.clone(), vsum.clone());
        crate::math::dana_fused_update(&mut tn, &mut vn, &mut sn, &g, 0.9, 0.05);
        let mut hatn = vec![0.0; k];
        crate::math::lookahead(&mut hatn, &tn, &sn, 0.9, 0.05);
        for (a, b) in t2.iter().zip(&tn) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in v2.iter().zip(&vn) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in s2.iter().zip(&sn) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in hat.iter().zip(&hatn) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}

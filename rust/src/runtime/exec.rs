//! Typed execution wrappers over compiled PJRT executables.

use super::manifest::{UpdateKernel, Variant};

/// Model input batch (MLP takes f32 features, the LM takes i32 tokens).
#[derive(Debug, Clone, Copy)]
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

fn literal_1d_f32(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

fn literal_shaped_f32(data: &[f32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape f32{dims:?}: {e:?}"))
}

fn literal_shaped_i32(data: &[i32], dims: &[usize]) -> anyhow::Result<xla::Literal> {
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims_i64)
        .map_err(|e| anyhow::anyhow!("reshape i32{dims:?}: {e:?}"))
}

fn scalar_from(lit: &xla::Literal) -> anyhow::Result<f32> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("scalar read: {e:?}"))?
        .first()
        .copied()
        .ok_or_else(|| anyhow::anyhow!("empty scalar literal"))
}

/// A compiled model variant: train + eval executables and shape metadata.
pub struct Model {
    variant: Variant,
    train: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
}

impl Model {
    pub(super) fn new(
        variant: Variant,
        train: xla::PjRtLoadedExecutable,
        eval: xla::PjRtLoadedExecutable,
    ) -> Self {
        Model { variant, train, eval }
    }

    pub fn variant(&self) -> &Variant {
        &self.variant
    }

    pub fn param_count(&self) -> usize {
        self.variant.param_count
    }

    pub fn batch(&self) -> usize {
        self.variant.batch
    }

    fn inputs(&self, params: &[f32], x: Input<'_>, y: &[i32]) -> anyhow::Result<[xla::Literal; 3]> {
        anyhow::ensure!(
            params.len() == self.variant.param_count,
            "params len {} != {}",
            params.len(),
            self.variant.param_count
        );
        let expect_x: usize = self.variant.x_shape.iter().product();
        let expect_y: usize = self.variant.y_shape.iter().product();
        anyhow::ensure!(y.len() == expect_y, "y len {} != {}", y.len(), expect_y);
        let xl = match (x, self.variant.x_dtype.as_str()) {
            (Input::F32(d), "f32") => {
                anyhow::ensure!(d.len() == expect_x, "x len {} != {}", d.len(), expect_x);
                literal_shaped_f32(d, &self.variant.x_shape)?
            }
            (Input::I32(d), "i32") => {
                anyhow::ensure!(d.len() == expect_x, "x len {} != {}", d.len(), expect_x);
                literal_shaped_i32(d, &self.variant.x_shape)?
            }
            (got, want) => anyhow::bail!(
                "variant {} expects x dtype {want}, got {:?}",
                self.variant.name,
                match got {
                    Input::F32(_) => "f32",
                    Input::I32(_) => "i32",
                }
            ),
        };
        let yl = literal_shaped_i32(y, &self.variant.y_shape)?;
        Ok([literal_1d_f32(params), xl, yl])
    }

    fn run2(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal; 3],
    ) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        let result = exe
            .execute::<xla::Literal>(inputs.as_slice())
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let mut parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 2, "expected 2 outputs, got {}", parts.len());
        let second = parts.pop().unwrap();
        let first = parts.pop().unwrap();
        Ok((first, second))
    }

    /// `train_step(params, x, y) -> (loss, grads[P])`.
    pub fn train_step(
        &self,
        params: &[f32],
        x: Input<'_>,
        y: &[i32],
    ) -> anyhow::Result<(f32, Vec<f32>)> {
        let inputs = self.inputs(params, x, y)?;
        let (loss, grads) = Self::run2(&self.train, &inputs)?;
        let loss = scalar_from(&loss)?;
        let grads = grads
            .to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("grads read: {e:?}"))?;
        anyhow::ensure!(grads.len() == self.variant.param_count, "bad grads len");
        Ok((loss, grads))
    }

    /// `eval_step(params, x, y) -> (mean loss, correct count)`.
    pub fn eval_step(
        &self,
        params: &[f32],
        x: Input<'_>,
        y: &[i32],
    ) -> anyhow::Result<(f32, f32)> {
        let inputs = self.inputs(params, x, y)?;
        let (loss, correct) = Self::run2(&self.eval, &inputs)?;
        Ok((scalar_from(&loss)?, scalar_from(&correct)?))
    }
}

/// The fused DANA master-update kernel executed through PJRT (ablation
/// against the native loop in `math::dana_fused_update`).
pub struct UpdateKernelExec {
    meta: UpdateKernel,
    exe: xla::PjRtLoadedExecutable,
}

impl UpdateKernelExec {
    pub(super) fn new(meta: UpdateKernel, exe: xla::PjRtLoadedExecutable) -> Self {
        UpdateKernelExec { meta, exe }
    }

    pub fn k(&self) -> usize {
        self.meta.k
    }

    #[allow(clippy::type_complexity)]
    pub fn apply(
        &self,
        gamma: f32,
        eta: f32,
        theta: &[f32],
        v: &[f32],
        vsum: &[f32],
        g: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let k = self.meta.k;
        for (name, s) in [("theta", theta), ("v", v), ("vsum", vsum), ("g", g)] {
            anyhow::ensure!(s.len() == k, "{name} len {} != {k}", s.len());
        }
        let inputs = [
            literal_1d_f32(&[gamma]),
            literal_1d_f32(&[eta]),
            literal_1d_f32(theta),
            literal_1d_f32(v),
            literal_1d_f32(vsum),
            literal_1d_f32(g),
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 4, "expected 4 outputs");
        let mut out: Vec<Vec<f32>> = Vec::with_capacity(4);
        for p in parts {
            out.push(p.to_vec::<f32>().map_err(|e| anyhow::anyhow!("read: {e:?}"))?);
        }
        let hat = out.pop().unwrap();
        let vsum2 = out.pop().unwrap();
        let v2 = out.pop().unwrap();
        let theta2 = out.pop().unwrap();
        Ok((theta2, v2, vsum2, hat))
    }
}

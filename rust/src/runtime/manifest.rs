//! `artifacts/manifest.json` schema — the contract between the python
//! compile path (`python/compile/aot.py`) and the rust runtime.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

pub const SUPPORTED_FORMAT: usize = 1;

#[derive(Debug, Clone, PartialEq)]
pub struct GoldenRecord {
    pub loss: f64,
    pub grad_l2: f64,
    pub grad_prefix: Vec<f64>,
    pub eval_loss: f64,
    pub eval_correct: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Variant {
    pub name: String,
    /// "mlp" | "lm"
    pub kind: String,
    pub param_count: usize,
    pub batch: usize,
    pub x_shape: Vec<usize>,
    pub y_shape: Vec<usize>,
    /// "f32" | "i32"
    pub x_dtype: String,
    pub train_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub init_params: PathBuf,
    pub golden_x: PathBuf,
    pub golden_y: PathBuf,
    pub golden: GoldenRecord,
}

#[derive(Debug, Clone, PartialEq)]
pub struct UpdateKernel {
    pub k: usize,
    pub file: PathBuf,
    pub out_l2: Vec<f64>,
    pub gamma: f64,
    pub eta: f64,
    pub seed: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<Variant>,
    pub update_kernel: Option<UpdateKernel>,
}

fn req<'a>(j: &'a Json, key: &str, ctx: &str) -> anyhow::Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow::anyhow!("manifest: missing {ctx}.{key}"))
}

fn req_usize(j: &Json, key: &str, ctx: &str) -> anyhow::Result<usize> {
    req(j, key, ctx)?
        .as_usize()
        .ok_or_else(|| anyhow::anyhow!("manifest: {ctx}.{key} not a usize"))
}

fn req_f64(j: &Json, key: &str, ctx: &str) -> anyhow::Result<f64> {
    req(j, key, ctx)?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("manifest: {ctx}.{key} not a number"))
}

fn req_str(j: &Json, key: &str, ctx: &str) -> anyhow::Result<String> {
    Ok(req(j, key, ctx)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("manifest: {ctx}.{key} not a string"))?
        .to_string())
}

fn usize_arr(j: &Json, key: &str, ctx: &str) -> anyhow::Result<Vec<usize>> {
    req(j, key, ctx)?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("manifest: {ctx}.{key} not an array"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("{ctx}.{key}: bad element")))
        .collect()
}

fn f64_arr(j: &Json, key: &str, ctx: &str) -> anyhow::Result<Vec<f64>> {
    req(j, key, ctx)?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("manifest: {ctx}.{key} not an array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("{ctx}.{key}: bad element")))
        .collect()
}

impl Manifest {
    /// Load and validate `dir/manifest.json`; verifies referenced files
    /// exist and init-param sizes match declared param counts.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let fmt = req_usize(&j, "format_version", "root")?;
        anyhow::ensure!(
            fmt == SUPPORTED_FORMAT,
            "manifest format {fmt} != supported {SUPPORTED_FORMAT}"
        );
        let mut variants = Vec::new();
        for (i, vj) in req(&j, "variants", "root")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest: variants not an array"))?
            .iter()
            .enumerate()
        {
            let ctx = format!("variants[{i}]");
            let files = req(vj, "files", &ctx)?;
            let gold = req(vj, "golden", &ctx)?;
            let path = |key: &str| -> anyhow::Result<PathBuf> {
                Ok(dir.join(req_str(files, key, &format!("{ctx}.files"))?))
            };
            let v = Variant {
                name: req_str(vj, "name", &ctx)?,
                kind: req_str(vj, "kind", &ctx)?,
                param_count: req_usize(vj, "param_count", &ctx)?,
                batch: req_usize(vj, "batch", &ctx)?,
                x_shape: usize_arr(vj, "x_shape", &ctx)?,
                y_shape: usize_arr(vj, "y_shape", &ctx)?,
                x_dtype: req_str(vj, "x_dtype", &ctx)?,
                train_hlo: path("train")?,
                eval_hlo: path("eval")?,
                init_params: path("init")?,
                golden_x: path("golden_x")?,
                golden_y: path("golden_y")?,
                golden: GoldenRecord {
                    loss: req_f64(gold, "loss", &ctx)?,
                    grad_l2: req_f64(gold, "grad_l2", &ctx)?,
                    grad_prefix: f64_arr(gold, "grad_prefix", &ctx)?,
                    eval_loss: req_f64(gold, "eval_loss", &ctx)?,
                    eval_correct: req_f64(gold, "eval_correct", &ctx)?,
                },
            };
            for p in [&v.train_hlo, &v.eval_hlo, &v.init_params, &v.golden_x, &v.golden_y] {
                anyhow::ensure!(p.exists(), "manifest references missing file {}", p.display());
            }
            let init_bytes = std::fs::metadata(&v.init_params)?.len() as usize;
            anyhow::ensure!(
                init_bytes == 4 * v.param_count,
                "{}: init file {} bytes != 4*{}",
                v.name,
                init_bytes,
                v.param_count
            );
            variants.push(v);
        }
        let update_kernel = match j.get("update_kernel") {
            None => None,
            Some(uj) => {
                let g = req(uj, "golden", "update_kernel")?;
                Some(UpdateKernel {
                    k: req_usize(uj, "k", "update_kernel")?,
                    file: dir.join(req_str(uj, "file", "update_kernel")?),
                    out_l2: f64_arr(g, "out_l2", "update_kernel.golden")?,
                    gamma: req_f64(g, "gamma", "update_kernel.golden")?,
                    eta: req_f64(g, "eta", "update_kernel.golden")?,
                    seed: req_usize(g, "seed", "update_kernel.golden")? as u64,
                })
            }
        };
        Ok(Manifest { dir: dir.to_path_buf(), variants, update_kernel })
    }

    pub fn variant(&self, name: &str) -> anyhow::Result<&Variant> {
        self.variants
            .iter()
            .find(|v| v.name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "variant {name:?} not in manifest (have: {})",
                    self.variants.iter().map(|v| v.name.as_str()).collect::<Vec<_>>().join(", ")
                )
            })
    }
}

/// Read a raw little-endian f32 file (e.g. `<name>.init.f32`).
pub fn read_f32_file(path: &Path) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{}: not a multiple of 4", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a raw little-endian i32 file.
pub fn read_i32_file(path: &Path) -> anyhow::Result<Vec<i32>> {
    let bytes = std::fs::read(path)?;
    anyhow::ensure!(bytes.len() % 4 == 0, "{}: not a multiple of 4", path.display());
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest_when_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.variants.len() >= 4);
        let v = m.variant("mlp_c10_ref").unwrap();
        assert_eq!(v.kind, "mlp");
        assert_eq!(v.x_shape[0], v.batch);
        assert!(m.variant("nope").is_err());
        let init = read_f32_file(&v.init_params).unwrap();
        assert_eq!(init.len(), v.param_count);
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn f32_reader_round_trips() {
        let dir = std::env::temp_dir().join(format!("mani_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.f32");
        let vals = [1.5f32, -2.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&p, bytes).unwrap();
        assert_eq!(read_f32_file(&p).unwrap(), vals);
        std::fs::remove_dir_all(dir).ok();
    }
}

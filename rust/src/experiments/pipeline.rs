//! Pipeline-depth sweep: what does compute/communication overlap buy, and
//! what does its extra staleness cost?
//!
//! The pipelined worker runtime (`--pipeline-depth D`, DESIGN.md §10)
//! trades exactly `D` extra *own* steps of deterministic staleness for
//! hiding the master round trip behind compute.  This sweep quantifies
//! both sides on the seeded synthetic quadratic (artifact-free, simulated
//! clock with `--rtt > 0` so communication actually costs time): for each
//! algorithm × worker count × depth it reports the simulated time to run
//! the step budget (the throughput win), the final loss (the staleness
//! cost), and the mean gap/lag (the paper's staleness measurements,
//! which shift by ~`D·N` master steps).  The question it answers: does
//! DANA's depth-extrapolated look-ahead keep the loss flat where the
//! momentum baselines degrade as `D` grows?
//!
//! Run: `dana experiment pipeline [--full] [--out DIR]` → `pipeline.csv`
//! + a printed table.

use super::ExpOptions;
use crate::config::{TrainConfig, Workload};
use crate::optim::AlgorithmKind;
use crate::train::sim_trainer;
use crate::util::csvw::{fnum, CsvWriter};

/// Parameter count of the synthetic quadratic (matches the churn sweep).
const K: usize = 2048;

/// Simulated pull→params round-trip time, in the gamma clock's units
/// (mean batch time is ~the per-worker batch size, 128): a depth-0
/// worker loses ~25% of its cycle to communication, which a depth-1
/// pipeline mostly hides.
const RTT: f64 = 32.0;

fn sweep_cfg(
    alg: AlgorithmKind,
    workers: usize,
    depth: usize,
    epochs: f64,
    seed: u64,
    encoding: crate::net::Encoding,
) -> TrainConfig {
    let mut cfg = TrainConfig::preset(Workload::C10, alg, workers, epochs);
    cfg.seed = seed;
    cfg.metrics_every = 5;
    cfg.pipeline_depth = depth;
    cfg.rtt = RTT;
    cfg.encoding = encoding;
    cfg
}

/// The depth × workers sweep (registered as experiment id `pipeline`).
pub fn pipeline(opts: &ExpOptions) -> anyhow::Result<()> {
    let epochs = if opts.quick { 4.0 } else { 16.0 };
    let (depths, workers): (&[usize], &[usize]) = if opts.quick {
        (&[0, 1, 2], &[4, 8])
    } else {
        (&[0, 1, 2, 4], &[4, 8, 16])
    };
    let algs = [
        AlgorithmKind::DanaZero,
        AlgorithmKind::DanaDc,
        AlgorithmKind::DanaSlim,
        AlgorithmKind::NagAsgd,
        AlgorithmKind::Lwp,
        AlgorithmKind::Asgd,
    ];
    let mut w = CsvWriter::create(
        &opts.out_dir.join("pipeline.csv"),
        &[
            "algorithm",
            "n_workers",
            "depth",
            "rtt",
            "encoding",
            "seed",
            "final_loss",
            "dloss_vs_d0",
            "mean_gap",
            "mean_lag",
            "sim_time",
            "speedup_vs_d0",
        ],
    )?;
    println!(
        "pipeline sweep: {} algorithms x workers {workers:?} x depth {depths:?}, rtt={RTT}, \
         k={K}, encoding={}",
        algs.len(),
        opts.encoding
    );
    println!(
        "{:<11} {:>3} {:>3} {:>11} {:>10} {:>8} {:>10} {:>8}",
        "algorithm", "N", "D", "final_loss", "dloss", "lag", "sim_time", "speedup"
    );
    for &alg in &algs {
        for &n in workers {
            for seed in 1..=opts.seeds {
                let mut d0: Option<(f64, f64)> = None; // (loss, sim_time) at D=0
                for &depth in depths {
                    let rep = sim_trainer::run_synthetic(
                        &sweep_cfg(alg, n, depth, epochs, seed, opts.encoding),
                        K,
                    )?;
                    let (base_loss, base_time) =
                        *d0.get_or_insert((rep.final_test_loss, rep.sim_time));
                    let dloss = rep.final_test_loss - base_loss;
                    let speedup = base_time / rep.sim_time.max(1e-12);
                    println!(
                        "{:<11} {:>3} {:>3} {:>11.3e} {:>+10.2e} {:>8.1} {:>10.0} {:>8.2}x",
                        alg.name(),
                        n,
                        depth,
                        rep.final_test_loss,
                        dloss,
                        rep.mean_lag,
                        rep.sim_time,
                        speedup
                    );
                    w.row(&[
                        alg.name().to_string(),
                        n.to_string(),
                        depth.to_string(),
                        fnum(RTT),
                        opts.encoding.to_string(),
                        seed.to_string(),
                        fnum(rep.final_test_loss),
                        fnum(dloss),
                        fnum(rep.mean_gap),
                        fnum(rep.mean_lag),
                        fnum(rep.sim_time),
                        fnum(speedup),
                    ])?;
                }
            }
        }
    }
    Ok(())
}

//! Heterogeneous-environment experiments: Fig 6, Fig 13, Table 6.

use super::accuracy::{baseline_error, quick_epochs, run_grid};
use super::ExpOptions;
use crate::config::{TrainConfig, Workload};
use crate::optim::AlgorithmKind;
use crate::runtime::Engine;
use crate::sim::Environment;
use crate::train::sim_trainer;
use crate::util::csvw::{fnum, CsvWriter};

const HETERO_ALGS: [AlgorithmKind; 5] = [
    AlgorithmKind::DanaDc,
    AlgorithmKind::DanaSlim,
    AlgorithmKind::DcAsgd,
    AlgorithmKind::MultiAsgd,
    AlgorithmKind::NagAsgd,
];

fn worker_grid(opts: &ExpOptions) -> Vec<usize> {
    if opts.quick {
        vec![4, 8, 16, 32]
    } else {
        vec![4, 8, 16, 24, 32]
    }
}

/// Fig 6: final test error vs N in the heterogeneous environment.
pub fn fig6(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let epochs = quick_epochs(opts);
    let base = baseline_error(opts, &engine, Workload::C10, epochs)?;
    println!("fig6: hetero CIFAR-10 proxy (baseline err={base:.2}%)");
    let cells = run_grid(
        opts,
        &engine,
        Workload::C10,
        &HETERO_ALGS,
        &worker_grid(opts),
        epochs,
        Environment::Heterogeneous,
    )?;
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig6.csv"),
        &["algorithm", "n_workers", "mean_err", "std_err", "baseline_err"],
    )?;
    for c in &cells {
        w.row(&[
            c.alg.name().to_string(),
            c.n.to_string(),
            fnum(c.mean()),
            fnum(c.std()),
            fnum(base),
        ])?;
    }
    Ok(())
}

/// Fig 13: hetero final error (a) + convergence curves at N=8 (b).
pub fn fig13(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let epochs = quick_epochs(opts);
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig13.csv"),
        &["algorithm", "epoch", "test_error", "sim_time"],
    )?;
    for alg in HETERO_ALGS {
        let mut cfg = TrainConfig::preset(Workload::C10, alg, 8, epochs);
        cfg.env = Environment::Heterogeneous;
        cfg.eval_every_epochs = epochs / 12.0;
        cfg.artifacts_dir = opts.artifacts_dir.clone();
        let rep = sim_trainer::run(&cfg, &engine)?;
        println!("  {}", rep.summary());
        for p in &rep.curve {
            w.row(&[
                alg.name().to_string(),
                fnum(p.epoch),
                fnum(p.test_error),
                fnum(p.sim_time),
            ])?;
        }
    }
    Ok(())
}

/// Table 6: heterogeneous final accuracies (paper row format).
pub fn table6(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let epochs = quick_epochs(opts);
    let base = baseline_error(opts, &engine, Workload::C10, epochs)?;
    let ns = worker_grid(opts);
    let cells = run_grid(
        opts,
        &engine,
        Workload::C10,
        &HETERO_ALGS,
        &ns,
        epochs,
        Environment::Heterogeneous,
    )?;
    let mut w = CsvWriter::create(
        &opts.out_dir.join("table6.csv"),
        &["algorithm", "n_workers", "mean_acc", "std"],
    )?;
    println!("\ntable6: hetero ResNet-20/C10 proxy ACCURACY (baseline {:.2}%)", 100.0 - base);
    print!("{:>8} |", "#Workers");
    for a in HETERO_ALGS {
        print!(" {:>18} |", a.name());
    }
    println!();
    for &n in &ns {
        print!("{n:>8} |");
        for a in HETERO_ALGS {
            let c = cells.iter().find(|c| c.alg == a && c.n == n).unwrap();
            print!(" {:>11.2} ± {:<4.2} |", 100.0 - c.mean(), c.std());
            w.row(&[
                a.name().to_string(),
                n.to_string(),
                fnum(100.0 - c.mean()),
                fnum(c.std()),
            ])?;
        }
        println!();
    }
    Ok(())
}

//! Gap experiments: Fig 2(a) worker-count sweep, Fig 2(b) algorithm
//! comparison, Fig 11 gradient-norm + normalized-gap traces.

use super::ExpOptions;
use crate::config::{TrainConfig, Workload};
use crate::optim::AlgorithmKind;
use crate::runtime::Engine;
use crate::train::sim_trainer;
use crate::util::csvw::{fnum, CsvWriter};

fn gap_config(opts: &ExpOptions, alg: AlgorithmKind, n: usize) -> TrainConfig {
    let epochs = if opts.quick { 4.0 } else { 12.0 };
    let mut cfg = TrainConfig::preset(Workload::C10, alg, n, epochs);
    cfg.artifacts_dir = opts.artifacts_dir.clone();
    let total = cfg.total_master_steps();
    cfg.metrics_every = (total / 400).max(1);
    cfg
}

/// Fig 2(a): ASGD gap trace for increasing cluster sizes.
pub fn fig2a(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig2a.csv"),
        &["n_workers", "step", "gap"],
    )?;
    for n in [1usize, 4, 8, 16] {
        let cfg = gap_config(opts, AlgorithmKind::Asgd, n);
        let rep = sim_trainer::run(&cfg, &engine)?;
        println!("  ASGD N={n:<3} mean gap={:.3e} mean lag={:.1}", rep.mean_gap, rep.mean_lag);
        for (step, gap) in &rep.gap_curve {
            w.row(&[n.to_string(), step.to_string(), fnum(*gap)])?;
        }
    }
    println!("  (paper Fig 2a shape: gap grows with N)");
    Ok(())
}

const FIG2B_ALGS: [AlgorithmKind; 6] = [
    AlgorithmKind::Asgd,
    AlgorithmKind::NagAsgd,
    AlgorithmKind::Lwp,
    AlgorithmKind::MultiAsgd,
    AlgorithmKind::DanaZero,
    AlgorithmKind::DanaDc,
];

/// Fig 2(b): gap per algorithm at N=8 on identical schedules.
pub fn fig2b(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig2b.csv"),
        &["algorithm", "step", "gap", "lag"],
    )?;
    let mut means = Vec::new();
    for alg in FIG2B_ALGS {
        let cfg = gap_config(opts, alg, 8);
        let rep = sim_trainer::run(&cfg, &engine)?;
        println!(
            "  {:<11} mean gap={:.3e} mean lag={:.1}",
            alg.name(),
            rep.mean_gap,
            rep.mean_lag
        );
        means.push((alg, rep.mean_gap, rep.mean_lag));
        for ((step, gap), (_, _lag)) in rep.gap_curve.iter().zip(rep.gap_curve.iter()) {
            w.row(&[
                alg.name().to_string(),
                step.to_string(),
                fnum(*gap),
                fnum(rep.mean_lag),
            ])?;
        }
    }
    // Expected ordering (paper): nag-asgd ≈ lwp >> multi >> dana ≈ asgd,
    // with identical lags across algorithms.
    let gap_of = |k: AlgorithmKind| means.iter().find(|m| m.0 == k).unwrap().1;
    println!(
        "  ordering check: nag/dana-zero gap ratio = {:.1}x (paper: ~an order of magnitude)",
        gap_of(AlgorithmKind::NagAsgd) / gap_of(AlgorithmKind::DanaZero).max(1e-12)
    );
    Ok(())
}

/// Fig 11: gradient-norm trace (a) and normalized gap (b) at N=8.
pub fn fig11(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig11.csv"),
        &["algorithm", "step", "grad_norm", "norm_gap"],
    )?;
    for alg in FIG2B_ALGS {
        let cfg = gap_config(opts, alg, 8);
        let rep = sim_trainer::run(&cfg, &engine)?;
        let mean_norm_gap: f64 = if rep.norm_gap_curve.is_empty() {
            0.0
        } else {
            rep.norm_gap_curve.iter().map(|x| x.1).sum::<f64>() / rep.norm_gap_curve.len() as f64
        };
        println!("  {:<11} mean normalized gap={mean_norm_gap:.3}", alg.name());
        for ((step, gn), (_, ng)) in rep.grad_norm_curve.iter().zip(&rep.norm_gap_curve) {
            w.row(&[alg.name().to_string(), step.to_string(), fnum(*gn), fnum(*ng)])?;
        }
    }
    println!("  (paper B.3: ASGD and DANA-Zero normalized gaps roughly coincide)");
    Ok(())
}

//! Accuracy experiments: Fig 4 (final error vs N), Fig 5 (convergence at
//! N=8), and the CIFAR tables 2–4.

use super::ExpOptions;
use crate::config::{TrainConfig, Workload};
use crate::optim::AlgorithmKind;
use crate::runtime::Engine;
use crate::train::{baseline, sim_trainer, TrainReport};
use crate::sim::Environment;
use crate::util::csvw::{fnum, CsvWriter};
use crate::util::stats;

/// One grid cell: algorithm x worker-count, aggregated over seeds.
#[derive(Debug, Clone)]
pub struct Cell {
    pub alg: AlgorithmKind,
    pub n: usize,
    pub errors: Vec<f64>,
    pub diverged: usize,
}

impl Cell {
    pub fn mean(&self) -> f64 {
        stats::summarize(&self.errors).mean
    }

    pub fn std(&self) -> f64 {
        stats::summarize(&self.errors).std
    }
}

pub(super) fn quick_epochs(opts: &ExpOptions) -> f64 {
    if opts.quick {
        6.0
    } else {
        24.0
    }
}

/// Run the (algorithms x worker-counts x seeds) grid for one workload.
pub fn run_grid(
    opts: &ExpOptions,
    engine: &Engine,
    workload: Workload,
    algs: &[AlgorithmKind],
    ns: &[usize],
    epochs: f64,
    env: Environment,
) -> anyhow::Result<Vec<Cell>> {
    let mut cells = Vec::new();
    for &alg in algs {
        for &n in ns {
            let mut cell = Cell { alg, n, errors: Vec::new(), diverged: 0 };
            for seed in 0..opts.seeds {
                let mut cfg = TrainConfig::preset(workload, alg, n, epochs);
                cfg.env = env;
                cfg.seed = seed + 1;
                cfg.artifacts_dir = opts.artifacts_dir.clone();
                let rep = sim_trainer::run(&cfg, engine)?;
                if rep.diverged {
                    cell.diverged += 1;
                }
                cell.errors.push(rep.final_test_error);
            }
            println!(
                "  {:<11} N={:<3} err={:6.2}% ± {:5.2}{}",
                alg.name(),
                n,
                cell.mean(),
                cell.std(),
                if cell.diverged > 0 {
                    format!("  ({}/{} diverged)", cell.diverged, opts.seeds)
                } else {
                    String::new()
                }
            );
            cells.push(cell);
        }
    }
    Ok(cells)
}

/// Baseline error for one workload (the dashed line in every figure).
pub fn baseline_error(
    opts: &ExpOptions,
    engine: &Engine,
    workload: Workload,
    epochs: f64,
) -> anyhow::Result<f64> {
    let mut cfg = TrainConfig::preset(workload, AlgorithmKind::DanaSlim, 1, epochs);
    cfg.artifacts_dir = opts.artifacts_dir.clone();
    let rep = baseline::run(&cfg, engine)?;
    Ok(rep.final_test_error)
}

fn write_grid_csv(
    opts: &ExpOptions,
    name: &str,
    workload: Workload,
    cells: &[Cell],
    base_err: f64,
) -> anyhow::Result<()> {
    let mut w = CsvWriter::create(
        &opts.out_dir.join(format!("{name}.csv")),
        &["workload", "algorithm", "n_workers", "mean_err", "std_err", "diverged", "baseline_err"],
    )?;
    for c in cells {
        w.row(&[
            workload.name().to_string(),
            c.alg.name().to_string(),
            c.n.to_string(),
            fnum(c.mean()),
            fnum(c.std()),
            c.diverged.to_string(),
            fnum(base_err),
        ])?;
    }
    Ok(())
}

fn worker_grid(opts: &ExpOptions) -> Vec<usize> {
    if opts.quick {
        vec![4, 8, 16, 32]
    } else {
        vec![4, 8, 12, 16, 20, 24, 28, 32]
    }
}

/// Fig 4: final test error vs number of workers, per workload panel.
pub fn fig4(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let panels: &[Workload] = if opts.quick {
        &[Workload::C10, Workload::C100]
    } else {
        &[Workload::C10, Workload::WrnC10, Workload::C100]
    };
    let epochs = quick_epochs(opts);
    for &wl in panels {
        println!("fig4 panel: {} (epochs={epochs})", wl.name());
        let base = baseline_error(opts, &engine, wl, epochs)?;
        println!("  baseline err={base:.2}%");
        let cells = run_grid(
            opts,
            &engine,
            wl,
            &AlgorithmKind::PAPER_SET,
            &worker_grid(opts),
            epochs,
            Environment::Homogeneous,
        )?;
        write_grid_csv(opts, &format!("fig4_{}", wl.name()), wl, &cells, base)?;
    }
    Ok(())
}

/// Fig 5: test-error convergence curves, 8 workers, all algorithms.
pub fn fig5(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let epochs = quick_epochs(opts);
    let wl = Workload::C10;
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig5.csv"),
        &["algorithm", "epoch", "test_error", "test_loss"],
    )?;
    // baseline curve
    let mut cfg = TrainConfig::preset(wl, AlgorithmKind::DanaSlim, 1, epochs);
    cfg.artifacts_dir = opts.artifacts_dir.clone();
    cfg.eval_every_epochs = epochs / 12.0;
    let rep = baseline::run(&cfg, &engine)?;
    dump_curve(&mut w, "baseline", &rep)?;
    for alg in AlgorithmKind::PAPER_SET {
        let mut cfg = TrainConfig::preset(wl, alg, 8, epochs);
        cfg.artifacts_dir = opts.artifacts_dir.clone();
        cfg.eval_every_epochs = epochs / 12.0;
        let rep = sim_trainer::run(&cfg, &engine)?;
        println!("  {}", rep.summary());
        dump_curve(&mut w, alg.name(), &rep)?;
    }
    Ok(())
}

fn dump_curve(w: &mut CsvWriter, name: &str, rep: &TrainReport) -> anyhow::Result<()> {
    for p in &rep.curve {
        w.row(&[
            name.to_string(),
            fnum(p.epoch),
            fnum(p.test_error),
            fnum(p.test_loss),
        ])?;
    }
    Ok(())
}

/// Tables 2–4: the full algorithm x N grid for one workload, printed in the
/// paper's row format (mean ± std accuracy, baseline in the header).
pub fn table(opts: &ExpOptions, workload: Workload, id: &str) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let epochs = quick_epochs(opts);
    let base = baseline_error(opts, &engine, workload, epochs)?;
    let algs = AlgorithmKind::PAPER_SET;
    let ns = worker_grid(opts);
    let cells = run_grid(
        opts,
        &engine,
        workload,
        &algs,
        &ns,
        epochs,
        Environment::Homogeneous,
    )?;
    write_grid_csv(opts, id, workload, &cells, base)?;
    // paper-style table: rows = N, columns = algorithms, accuracy%.
    println!("\n{id}: {} final test ACCURACY (baseline {:.2}%)", workload.name(), 100.0 - base);
    print!("{:>8} |", "#Workers");
    for a in algs {
        print!(" {:>18} |", a.name());
    }
    println!();
    for &n in &ns {
        print!("{n:>8} |");
        for a in algs {
            let c = cells.iter().find(|c| c.alg == a && c.n == n).unwrap();
            print!(" {:>11.2} ± {:<4.2} |", 100.0 - c.mean(), c.std());
        }
        println!();
    }
    Ok(())
}

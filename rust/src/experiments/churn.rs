//! Elastic-membership sweep: does DANA's staleness mitigation survive
//! cluster churn?
//!
//! "Asynchrony begets Momentum" (Mitliagkas et al. 2016) shows the
//! *effective* momentum of async SGD grows with the number of live
//! workers, and staleness-aware methods (Zhang et al. 2015) modulate the
//! step by observed staleness — which spikes exactly when membership
//! shifts.  This sweep runs the paper's algorithm set over leave / join /
//! straggler / composite-churn scenarios on the seeded synthetic quadratic
//! (no PJRT, no artifacts: the simulated-clock driver
//! [`sim_trainer::run_synthetic`] honors every cluster event including
//! straggler onset) and reports the final-loss / gap / lag deltas against
//! each algorithm's churn-free run.
//!
//! Run: `dana experiment churn [--full] [--out DIR]` → `churn.csv` + a
//! printed table.  Both leave policies (retire / fold) are swept for the
//! leave scenarios so the momentum-retirement knob is directly comparable.

use super::ExpOptions;
use crate::config::{TrainConfig, Workload};
use crate::optim::{AlgorithmKind, LeavePolicy};
use crate::sim::ChurnSchedule;
use crate::train::sim_trainer;
use crate::util::csvw::{fnum, CsvWriter};

/// Parameter count of the synthetic quadratic (big enough that momentum
/// and gap effects are not noise-dominated, small enough to sweep fast).
const K: usize = 2048;
const N_WORKERS: usize = 8;

const SCENARIOS: [(&str, &str); 5] = [
    ("static", ""),
    ("leave", "leave@0.3:2"),
    ("join", "join@0.5"),
    ("straggler", "slow@0.5:0=4x"),
    ("churny", "leave@0.25:1,join@0.4,slow@0.6:0=4x,leave@0.75"),
];

fn scenario_cfg(
    alg: AlgorithmKind,
    spec: &str,
    policy: LeavePolicy,
    epochs: f64,
    seed: u64,
) -> anyhow::Result<TrainConfig> {
    let mut cfg = TrainConfig::preset(Workload::C10, alg, N_WORKERS, epochs);
    cfg.seed = seed;
    cfg.metrics_every = 5;
    cfg.churn = ChurnSchedule::parse(spec)?;
    cfg.leave_policy = policy;
    Ok(cfg)
}

/// The churn scenario sweep (registered as experiment id `churn`).
pub fn churn(opts: &ExpOptions) -> anyhow::Result<()> {
    let epochs = if opts.quick { 4.0 } else { 16.0 };
    let algs = AlgorithmKind::PAPER_SET;
    let mut w = CsvWriter::create(
        &opts.out_dir.join("churn.csv"),
        &[
            "algorithm",
            "scenario",
            "leave_policy",
            "seed",
            "final_loss",
            "dloss_vs_static",
            "mean_gap",
            "dgap_vs_static",
            "mean_lag",
            "dlag_vs_static",
            "joined",
            "left",
        ],
    )?;
    println!(
        "churn sweep: {} algorithms x {} scenarios x {} seed(s), N={}, k={}",
        algs.len(),
        SCENARIOS.len(),
        opts.seeds,
        N_WORKERS,
        K
    );
    println!(
        "{:<11} {:<10} {:<7} {:>11} {:>11} {:>9} {:>9}",
        "algorithm", "scenario", "policy", "final_loss", "dloss", "dgap", "dlag"
    );
    for alg in algs {
        for seed in 1..=opts.seeds {
            // churn-free reference for the deltas
            let base =
                sim_trainer::run_synthetic(&scenario_cfg(alg, "", LeavePolicy::Retire, epochs, seed)?, K)?;
            for (name, spec) in SCENARIOS {
                let has_leave = spec.contains("leave");
                let policies: &[LeavePolicy] = if has_leave {
                    &[LeavePolicy::Retire, LeavePolicy::Fold]
                } else {
                    &[LeavePolicy::Retire]
                };
                for &policy in policies {
                    let rep = if spec.is_empty() {
                        base.clone()
                    } else {
                        sim_trainer::run_synthetic(&scenario_cfg(alg, spec, policy, epochs, seed)?, K)?
                    };
                    let dloss = rep.final_test_loss - base.final_test_loss;
                    let dgap = rep.mean_gap - base.mean_gap;
                    let dlag = rep.mean_lag - base.mean_lag;
                    println!(
                        "{:<11} {:<10} {:<7} {:>11.3e} {:>+11.2e} {:>+9.2e} {:>+9.2}",
                        alg.name(),
                        name,
                        policy.name(),
                        rep.final_test_loss,
                        dloss,
                        dgap,
                        dlag
                    );
                    w.row(&[
                        alg.name().to_string(),
                        name.to_string(),
                        policy.name().to_string(),
                        seed.to_string(),
                        fnum(rep.final_test_loss),
                        fnum(dloss),
                        fnum(rep.mean_gap),
                        fnum(dgap),
                        fnum(rep.mean_lag),
                        fnum(dlag),
                        rep.workers_joined.to_string(),
                        rep.workers_left.to_string(),
                    ])?;
                }
            }
        }
    }
    Ok(())
}

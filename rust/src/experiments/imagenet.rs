//! ImageNet-proxy experiments: Fig 7 (large-N error + convergence) and
//! Table 5 (up to 128 workers).

use super::accuracy::{baseline_error, run_grid};
use super::ExpOptions;
use crate::config::{TrainConfig, Workload};
use crate::optim::AlgorithmKind;
use crate::runtime::Engine;
use crate::sim::Environment;
use crate::train::sim_trainer;
use crate::util::csvw::{fnum, CsvWriter};

// Table 5's algorithm columns (includes LWP, unlike the CIFAR tables).
const INET_ALGS: [AlgorithmKind; 7] = [
    AlgorithmKind::DanaDc,
    AlgorithmKind::DanaSlim,
    AlgorithmKind::DcAsgd,
    AlgorithmKind::MultiAsgd,
    AlgorithmKind::NagAsgd,
    AlgorithmKind::YellowFin,
    AlgorithmKind::Lwp,
];

fn epochs(opts: &ExpOptions) -> f64 {
    if opts.quick {
        3.0
    } else {
        12.0
    }
}

/// Fig 7(a): final error for N in {16, 32, 48, 64};
/// Fig 7(b): convergence curves at N=32.
pub fn fig7(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let e = epochs(opts);
    let wl = Workload::ImageNet;
    let base = baseline_error(opts, &engine, wl, e)?;
    println!("fig7: ImageNet proxy (baseline err={base:.2}%)");
    let ns: &[usize] = if opts.quick { &[16, 32, 64] } else { &[16, 32, 48, 64] };
    let cells = run_grid(
        opts,
        &engine,
        wl,
        &INET_ALGS,
        ns,
        e,
        Environment::Homogeneous,
    )?;
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig7a.csv"),
        &["algorithm", "n_workers", "mean_err", "std_err", "baseline_err"],
    )?;
    for c in &cells {
        w.row(&[
            c.alg.name().to_string(),
            c.n.to_string(),
            fnum(c.mean()),
            fnum(c.std()),
            fnum(base),
        ])?;
    }
    // 7(b): convergence at N=32
    let mut wb = CsvWriter::create(
        &opts.out_dir.join("fig7b.csv"),
        &["algorithm", "epoch", "test_error"],
    )?;
    for alg in [AlgorithmKind::DanaDc, AlgorithmKind::DanaSlim, AlgorithmKind::MultiAsgd, AlgorithmKind::NagAsgd] {
        let mut cfg = TrainConfig::preset(wl, alg, 32, e);
        cfg.eval_every_epochs = e / 10.0;
        cfg.artifacts_dir = opts.artifacts_dir.clone();
        let rep = sim_trainer::run(&cfg, &engine)?;
        println!("  {}", rep.summary());
        for p in &rep.curve {
            wb.row(&[alg.name().to_string(), fnum(p.epoch), fnum(p.test_error)])?;
        }
    }
    Ok(())
}

/// Table 5: final accuracies for N in {16, 32, 48, 64, 128}.
pub fn table5(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let e = epochs(opts);
    let wl = Workload::ImageNet;
    let base = baseline_error(opts, &engine, wl, e)?;
    let ns: Vec<usize> = if opts.quick {
        vec![16, 32, 64]
    } else {
        vec![16, 32, 48, 64, 128]
    };
    let cells = run_grid(opts, &engine, wl, &INET_ALGS, &ns, e, Environment::Homogeneous)?;
    let mut w = CsvWriter::create(
        &opts.out_dir.join("table5.csv"),
        &["algorithm", "n_workers", "mean_acc", "diverged"],
    )?;
    println!("\ntable5: ImageNet proxy ACCURACY (baseline {:.2}%)", 100.0 - base);
    print!("{:>8} |", "#Workers");
    for a in INET_ALGS {
        print!(" {:>11} |", a.name());
    }
    println!();
    for &n in &ns {
        print!("{n:>8} |");
        for a in INET_ALGS {
            let c = cells.iter().find(|c| c.alg == a && c.n == n).unwrap();
            let acc = 100.0 - c.mean();
            if c.diverged as u64 == opts.seeds {
                print!(" {:>11} |", "NaN");
            } else {
                print!(" {acc:>10.2}% |", );
            }
            w.row(&[
                a.name().to_string(),
                n.to_string(),
                fnum(acc),
                c.diverged.to_string(),
            ])?;
        }
        println!();
    }
    Ok(())
}

//! Experiment harness: regenerates every table and figure of the paper.
//! (Populated by the per-figure modules; see DESIGN.md §5 for the index.)

pub mod accuracy;
pub mod churn;
pub mod gap;
pub mod hetero;
pub mod imagenet;
pub mod pipeline;
pub mod speedup;

use std::path::PathBuf;

/// Shared experiment options (CLI-controlled).
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Quick mode: reduced steps/seeds/worker grids, shape-preserving.
    pub quick: bool,
    pub seeds: u64,
    pub out_dir: PathBuf,
    pub artifacts_dir: PathBuf,
    /// Gradient payload encoding applied push-side in the sweeps that
    /// support it (`--encoding`; wire v4 — see `net::codec`).  `None` =
    /// the exact-f32 behavior every figure defaults to.
    pub encoding: crate::net::Encoding,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            quick: true,
            seeds: 2,
            out_dir: PathBuf::from("results"),
            artifacts_dir: crate::config::default_artifacts_dir(),
            encoding: crate::net::Encoding::None,
        }
    }
}

/// All experiment ids, in paper order, plus this repo's own extensions
/// (`churn`: the elastic-membership sweep; `pipeline`: the worker
/// pipeline depth × workers sweep — both artifact-free).
pub const ALL_IDS: &[&str] = &[
    "fig2a", "fig2b", "fig3", "fig4", "fig5", "fig6", "fig7", "fig9", "fig10",
    "fig11", "fig12", "fig13", "table1", "table2", "table3", "table4", "table5",
    "table6", "churn", "pipeline",
];

/// Run one experiment by id.
pub fn run(id: &str, opts: &ExpOptions) -> anyhow::Result<()> {
    match id {
        "fig2a" => gap::fig2a(opts),
        "fig2b" => gap::fig2b(opts),
        "fig11" => gap::fig11(opts),
        "fig3" => speedup::fig3(opts),
        "fig12" => speedup::fig12(opts),
        "fig10" => speedup::fig10(opts),
        "fig9" => speedup::fig9(opts),
        "table1" => speedup::table1(opts),
        "fig4" => accuracy::fig4(opts),
        "fig5" => accuracy::fig5(opts),
        "table2" => accuracy::table(opts, crate::config::Workload::C10, "table2"),
        "table3" => accuracy::table(opts, crate::config::Workload::C10, "table3"),
        "table4" => accuracy::table(opts, crate::config::Workload::C100, "table4"),
        "fig7" => imagenet::fig7(opts),
        "table5" => imagenet::table5(opts),
        "fig6" => hetero::fig6(opts),
        "fig13" => hetero::fig13(opts),
        "table6" => hetero::table6(opts),
        "churn" => churn::churn(opts),
        "pipeline" => pipeline::pipeline(opts),
        "all" => {
            for id in ALL_IDS {
                println!("=== {id} ===");
                run(id, opts)?;
            }
            Ok(())
        }
        other => anyhow::bail!("unknown experiment {other:?}; known: {}, all", ALL_IDS.join(", ")),
    }
}

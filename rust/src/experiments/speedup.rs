//! Timing/scaling experiments: Fig 3 (gamma pdfs), Fig 9 (total-batch-size
//! scaling), Fig 10 (cloud speedup + error), Fig 12 (theoretical speedup),
//! Table 1 (accuracy/time/speedup per total batch).

use super::ExpOptions;
use crate::config::{TrainConfig, Workload};
use crate::optim::AlgorithmKind;
use crate::runtime::Engine;
use crate::sim::gamma::{Environment, ExecTimeModel};
use crate::sim::speedup as sp;
use crate::train::{sim_trainer, ssgd};
use crate::util::csvw::{fnum, CsvWriter};
use crate::util::rng::Rng;

/// Fig 3: empirical pdf of batch execution time, homo vs hetero.
pub fn fig3(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig3.csv"),
        &["env", "bucket_lo", "bucket_hi", "prob"],
    )?;
    let b = 128usize;
    let samples = if opts.quick { 200_000 } else { 1_000_000 };
    for env in [Environment::Homogeneous, Environment::Heterogeneous] {
        // resample the cluster every 800 draws so machine-level variance
        // shows up in the pdf (as in Fig 3's "many clusters" view)
        let mut all = Vec::with_capacity(samples);
        let mut seed = 0u64;
        while all.len() < samples {
            let mut rng = Rng::new(seed);
            seed += 1;
            let m = ExecTimeModel::new(env, 8, b, &mut rng);
            for j in 0..8 {
                for _ in 0..100 {
                    all.push(m.sample(j, &mut rng));
                }
            }
        }
        let tail = all.iter().filter(|&&t| t > 1.25 * b as f64).count() as f64
            / all.len() as f64;
        let mean = all.iter().sum::<f64>() / all.len() as f64;
        println!(
            "  {env:?}: mean={mean:.1} (B={b}), P[t > 1.25B] = {:.1}% (paper: homo 1%, hetero 27.9%)",
            100.0 * tail
        );
        // histogram over [0, 4B) in 64 buckets
        let buckets = 64usize;
        let hi = 4.0 * b as f64;
        let mut counts = vec![0usize; buckets];
        for &t in &all {
            let i = ((t / hi) * buckets as f64) as usize;
            counts[i.min(buckets - 1)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            w.row(&[
                format!("{env:?}"),
                fnum(i as f64 * hi / buckets as f64),
                fnum((i + 1) as f64 * hi / buckets as f64),
                fnum(c as f64 / all.len() as f64),
            ])?;
        }
    }
    Ok(())
}

/// Fig 12: theoretical async/sync speedup from the gamma model alone.
pub fn fig12(opts: &ExpOptions) -> anyhow::Result<()> {
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig12.csv"),
        &["env", "n_workers", "async_speedup", "sync_speedup"],
    )?;
    let ns: Vec<usize> = if opts.quick {
        vec![1, 2, 4, 8, 16, 32, 64]
    } else {
        vec![1, 2, 4, 8, 12, 16, 24, 32, 48, 64]
    };
    let (bpw, seeds) = if opts.quick { (60, 4) } else { (200, 10) };
    for env in [Environment::Homogeneous, Environment::Heterogeneous] {
        let pts = sp::speedup_sweep(env, &ns, 128, bpw, seeds);
        println!("  {env:?}:");
        for p in &pts {
            println!(
                "    N={:<3} async={:6.2}x sync={:6.2}x (ratio {:.2})",
                p.n_workers,
                p.async_speedup,
                p.sync_speedup,
                p.async_speedup / p.sync_speedup
            );
            w.row(&[
                format!("{env:?}"),
                p.n_workers.to_string(),
                fnum(p.async_speedup),
                fnum(p.sync_speedup),
            ])?;
        }
    }
    println!("  (paper Fig 12: async near-linear; sync plateaus, badly under hetero)");
    Ok(())
}

/// Fig 10: DANA-Slim speedup (solid) + final error (dashed) vs N — the
/// cloud experiment reproduced over the simulated cluster.
pub fn fig10(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let epochs = if opts.quick { 5.0 } else { 16.0 };
    let ns: Vec<usize> = if opts.quick {
        vec![1, 4, 8, 16, 24]
    } else {
        vec![1, 2, 4, 8, 12, 16, 20, 24]
    };
    let mut w = CsvWriter::create(
        &opts.out_dir.join("fig10.csv"),
        &["n_workers", "speedup", "test_error"],
    )?;
    println!("fig10: DANA-Slim on simulated cloud (CIFAR-10 proxy, epochs={epochs})");
    let mut base_time = None;
    for &n in &ns {
        let mut cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, n, epochs);
        cfg.artifacts_dir = opts.artifacts_dir.clone();
        let rep = sim_trainer::run(&cfg, &engine)?;
        let t = rep.sim_time;
        let speedup = match base_time {
            None => {
                base_time = Some(t);
                1.0
            }
            Some(b) => b / t,
        };
        println!(
            "  N={n:<3} speedup={speedup:6.2}x err={:6.2}%",
            rep.final_test_error
        );
        w.row(&[n.to_string(), fnum(speedup), fnum(rep.final_test_error)])?;
    }
    Ok(())
}

const TABLE1_BATCHES: [usize; 4] = [256, 512, 1024, 2048];

/// Fig 9 / Table 1 shared runs: 8 workers, total batch in {256..2048}
/// (per-worker batch = total/8), DANA-Slim vs Multi-ASGD vs SSGD.
fn batch_scaling_runs(
    opts: &ExpOptions,
    engine: &Engine,
    total_batch: usize,
    epochs: f64,
    curves: bool,
) -> anyhow::Result<Vec<(String, crate::train::TrainReport)>> {
    let per_worker = total_batch / 8;
    let mk_cfg = |alg| {
        let mut cfg = TrainConfig::preset(Workload::C10, alg, 8, epochs).with_batch(per_worker);
        cfg.artifacts_dir = opts.artifacts_dir.clone();
        if curves {
            cfg.eval_every_epochs = epochs / 10.0;
        }
        cfg
    };
    let mut out = Vec::new();
    for alg in [AlgorithmKind::DanaSlim, AlgorithmKind::MultiAsgd] {
        let rep = sim_trainer::run(&mk_cfg(alg), engine)?;
        out.push((alg.name().to_string(), rep));
    }
    let rep = ssgd::run(&mk_cfg(AlgorithmKind::DanaSlim), engine)?;
    out.push(("ssgd".to_string(), rep));
    Ok(out)
}

/// Fig 9: final error (a) + convergence at total batch 2048 (b).
pub fn fig9(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let epochs = if opts.quick { 5.0 } else { 16.0 };
    let mut wa = CsvWriter::create(
        &opts.out_dir.join("fig9a.csv"),
        &["algorithm", "total_batch", "test_error"],
    )?;
    println!("fig9: total-batch-size scaling, 8 workers (epochs={epochs})");
    for &tb in &TABLE1_BATCHES {
        let runs = batch_scaling_runs(opts, &engine, tb, epochs, false)?;
        for (name, rep) in &runs {
            println!("  B={tb:<5} {:<10} err={:6.2}%", name, rep.final_test_error);
            wa.row(&[name.clone(), tb.to_string(), fnum(rep.final_test_error)])?;
        }
    }
    // 9(b): convergence curves at total batch 2048
    let mut wb = CsvWriter::create(
        &opts.out_dir.join("fig9b.csv"),
        &["algorithm", "epoch", "test_error", "sim_time"],
    )?;
    for (name, rep) in batch_scaling_runs(opts, &engine, 2048, epochs, true)? {
        for p in &rep.curve {
            wb.row(&[name.clone(), fnum(p.epoch), fnum(p.test_error), fnum(p.sim_time)])?;
        }
    }
    Ok(())
}

/// Table 1: accuracy / simulated time / speedup-over-1-worker per total
/// batch size.
pub fn table1(opts: &ExpOptions) -> anyhow::Result<()> {
    let engine = Engine::cpu(&opts.artifacts_dir)?;
    let epochs = if opts.quick { 5.0 } else { 16.0 };
    let mut w = CsvWriter::create(
        &opts.out_dir.join("table1.csv"),
        &["total_batch", "algorithm", "accuracy", "sim_time", "speedup"],
    )?;
    println!("\ntable1: 8-worker scaling (simulated time units; speedup vs 1 worker)");
    println!(
        "{:>10} | {:<10} | {:>9} | {:>12} | {:>8}",
        "TotalBatch", "Algorithm", "Accuracy", "SimTime", "Speedup"
    );
    for &tb in &TABLE1_BATCHES {
        let per_worker = tb / 8;
        // single-worker reference time for the same number of batches
        let steps = {
            let cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, epochs)
                .with_batch(per_worker);
            cfg.total_master_steps() as usize
        };
        let base_time = sp::single_worker_time(Environment::Homogeneous, per_worker, steps, 99);
        for (name, rep) in batch_scaling_runs(opts, &engine, tb, epochs, false)? {
            let speedup = base_time / rep.sim_time;
            println!(
                "{tb:>10} | {name:<10} | {:>8.2}% | {:>12.0} | {speedup:>7.2}x",
                100.0 - rep.final_test_error,
                rep.sim_time
            );
            w.row(&[
                tb.to_string(),
                name,
                fnum(100.0 - rep.final_test_error),
                fnum(rep.sim_time),
                fnum(speedup),
            ])?;
        }
    }
    println!("  (paper Table 1 shape: ASGD speedup > SSGD; accuracy comparable)");
    Ok(())
}

//! The unified pipelined worker driver — ONE training loop beneath every
//! asynchronous backend.
//!
//! Before this module, the repo carried three near-copies of the worker
//! loop (`sim_trainer`, `real_async`, plus the shared scaffolding in
//! `ssgd`/`baseline`), every one of them strictly synchronous: pull →
//! compute → push, each cycle eating a full master round trip of idle
//! time.  This driver folds them into one engine with a configurable
//! **pipeline window** `--pipeline-depth D`: a worker keeps `D + 1`
//! batches in flight, issuing the pull for batch `n + D + 1` while the
//! push for batch `n` is still settling — communication overlaps compute,
//! at the cost of exactly `D` extra *own* steps of known, deterministic
//! staleness.  That is precisely the staleness DANA's look-ahead is built
//! to absorb: the driver forwards the depth to the master
//! ([`Master::set_pipeline_depth`]), DANA/DANA-DC extrapolate their Eq 11
//! prediction `D` extra momentum-only steps, NAG-ASGD sends the
//! extrapolated future position, LWP stretches τ by the in-flight
//! multiplicity, and the servers judge each push against the pull its
//! gradient was actually computed on (per-slot pull windows).
//!
//! Two [`WorkerBackend`]s drive the same cycle:
//!
//! * [`run_sim`] — the simulated-clock backend (§5.1/§5.2): completions
//!   come from the gamma execution-time model via
//!   [`AsyncSchedule`] (which models the pipeline's timing too — with
//!   `--rtt > 0` a depth-0 worker stalls a round trip per cycle while a
//!   pipelined one hides it), gradients are computed on the driver
//!   thread, and the pipeline window is the explicit [`PullWindow`];
//! * [`run_threads`] — the real-thread backend (§5.4): one OS thread per
//!   worker over an mpsc FIFO; the pipeline window *is* the worker's
//!   channel queue (the master keeps `D + 1` parameter messages in
//!   flight per worker).
//!
//! Both run unchanged against an in-process master (monolithic or
//! sharded) or a [`crate::net::RemoteMaster`] — where depth `D ≥ 1`
//! additionally switches pushes to the deferred-ack send path, so a
//! worker cycle costs one combined round trip instead of two.
//!
//! **`D = 0` is bit-for-bit the pre-pipeline synchronous driver** for
//! every algorithm and backend: the window degenerates to one buffer
//! rotated in place, the schedule is untouched (`rtt = 0` leaves the
//! completion stream identical at any depth), the staleness hints are
//! exact no-ops at zero, and the servers' pull windows reproduce the
//! classic single-`sent` overwrite semantics.  The churn/net/striped
//! equivalence suites pin this; `rust/tests/pipeline.rs` pins the `D ≥ 1`
//! determinism and the `+D` lag-histogram shift.

use crate::config::TrainConfig;
use crate::net::codec::Compressor;
use crate::net::Encoding;
use crate::optim::WorkerState;
use crate::server::Master;
use crate::sim::{AsyncSchedule, ChurnAction, ClusterEvent, Completion, ExecTimeModel};
use crate::train::real_async::{StepFn, WorkerRule};
use crate::train::{EvalPoint, TrainReport};
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::sync::mpsc;

// ------------------------------------------------------------ shared
// bookkeeping (also used by the synchronous ssgd/baseline drivers)

/// Periodic-eval cadence in master steps (0 = only the final eval).
pub(crate) fn eval_cadence(cfg: &TrainConfig) -> u64 {
    if cfg.eval_every_epochs > 0.0 {
        (cfg.eval_every_epochs * cfg.schedule.steps_per_epoch as f64).round() as u64
    } else {
        0
    }
}

/// Train-loss subsampling stride: ~200 points over the run.
pub(crate) fn loss_sample_every(total: u64) -> u64 {
    (total / 200).max(1)
}

/// The push-side gradient compressor for an **in-process** run
/// (`--encoding` without `--master`): the same quantize/sparsify +
/// error-feedback transform [`crate::net::RemoteMaster`] applies on the
/// wire, so compression experiments can be simulated without a server.
/// Against a remote master the client owns the transform — the driver
/// must never apply it a second time, so this returns an inert
/// [`Encoding::None`] compressor there.
fn in_process_compressor(cfg: &TrainConfig) -> Compressor {
    Compressor::new(if cfg.master_addr.is_none() { cfg.encoding } else { Encoding::None })
}

/// Final-eval epilogue shared by every driver: record the last
/// evaluation and apply the divergence convention (a non-finite loss
/// scores chance accuracy, the paper's convention).
pub(crate) fn finish_eval(report: &mut TrainReport, loss: f64, err: f64) {
    report.final_test_loss = loss;
    report.final_test_error = err;
    if !loss.is_finite() {
        report.diverged = true;
        report.final_test_error = 100.0;
    }
}

/// Hard cap on [`TrainReport::lag_curve`] points.  The per-push lag rows
/// are the one report series that scales with *total steps × workers*
/// rather than eval cadence; a long daemon-fed run used to grow it
/// without bound (and serialize megabytes of JSON nobody plots).  Below
/// the cap the curve is exact; above it, every stride-th row is kept —
/// uniform in step order, so quantiles and plots are unbiased.
pub(crate) const LAG_CURVE_CAP: usize = 50_000;

/// Fold the server's metric taps into the report (simulated backends,
/// where the full rows are available locally).
/// After the final drain, report per-server step counts for fan-out
/// placements (empty — and silent — for masters with a single home).
/// Counts are read fresh from each server, so the CI smoke can assert
/// the client-side step count against this line.  Per-group counts may
/// legitimately differ when pushes were lost to a failed group.
fn print_placement(server: &mut dyn Master) {
    let groups = server.placement_groups();
    if groups.is_empty() {
        return;
    }
    let detail: Vec<String> = groups.iter().map(|(ep, s)| format!("{ep}={s}")).collect();
    println!(
        "placement: {} groups, cluster steps {} [{}]",
        groups.len(),
        server.steps_done(),
        detail.join(", ")
    );
}

fn fold_metrics(report: &mut TrainReport, server: &dyn Master) {
    report.mean_gap = server.metrics().mean_gap();
    report.mean_lag = server.metrics().mean_lag();
    let rows = server.metrics().rows();
    let stride = rows.len().div_ceil(LAG_CURVE_CAP).max(1);
    for (i, r) in rows.iter().enumerate() {
        report.gap_curve.push((r.step, r.gap));
        report.norm_gap_curve.push((r.step, r.norm_gap));
        report.grad_norm_curve.push((r.step, r.msg_norm));
        if i % stride == 0 {
            report.lag_curve.push((r.step, r.worker, r.lag));
        }
    }
}

/// Which backend a [`TrainConfig`] run executes on — the names the CLI
/// and experiment harness use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerBackend {
    /// Virtual gamma-model clock, gradients on the driver thread.
    SimClock,
    /// One OS thread per worker over an mpsc FIFO.
    Threads,
}

/// Artifact-free training on the seeded noisy quadratic, on either
/// backend — ONE definition of the synthetic harness behind `dana train
/// --synthetic`, the experiment sweeps, and the equivalence suites
/// (previously duplicated across `sim_trainer` and `real_async`).
pub fn run_synthetic(
    cfg: &TrainConfig,
    k: usize,
    backend: WorkerBackend,
) -> anyhow::Result<TrainReport> {
    use crate::train::real_async as ra;
    anyhow::ensure!(k > 0, "synthetic workload needs k > 0");
    let theta0 = ra::synthetic_theta0(k);
    let curv = ra::synthetic_curvature(k);
    match backend {
        WorkerBackend::SimClock => {
            let grad_curv = curv.clone();
            let mut grad_rng =
                Rng::new(cfg.seed ^ crate::train::sim_trainer::SYNTH_GRAD_STREAM);
            run_sim(
                cfg,
                &theta0,
                move |_w, params, msg: &mut Vec<f32>, want_loss| {
                    ra::synthetic_grad(params, &grad_curv, &mut grad_rng, msg);
                    // the loss costs another O(k) pass here, so honor want_loss
                    Ok(if want_loss {
                        ra::synthetic_loss(params, &grad_curv)
                    } else {
                        0.0
                    })
                },
                move |theta| Ok(ra::synthetic_eval(theta, &curv)),
            )
        }
        WorkerBackend::Threads => {
            let seed = cfg.seed;
            let make_step = {
                let curv = curv.clone();
                move |w: usize| -> anyhow::Result<StepFn> {
                    let curv = curv.clone();
                    let mut rng = ra::synthetic_worker_rng(seed, w);
                    Ok(Box::new(move |params: &[f32]| {
                        let mut g = vec![0.0f32; params.len()];
                        ra::synthetic_grad(params, &curv, &mut rng, &mut g);
                        Ok((ra::synthetic_loss(params, &curv) as f32, g))
                    }) as StepFn)
                }
            };
            run_threads(cfg, &theta0, &make_step, move |theta| {
                Ok(ra::synthetic_eval(theta, &curv))
            })
        }
    }
}

// ------------------------------------------------------------ the
// pipeline window (sim-clock backend; the thread backend's window lives
// in each worker's channel)

/// Per-worker FIFO of pulled parameter buffers, depth `D + 1`: the front
/// is what the worker's *currently completing* batch was computed on;
/// the pull issued after each push lands at the back, `D` batches ahead.
struct PullWindow {
    depth: usize,
    k: usize,
    bufs: Vec<VecDeque<Vec<f32>>>,
}

impl PullWindow {
    /// Prime every worker's window: `D + 1` pulls each, issued
    /// round-robin (worker-major per round) so the kickoff order matches
    /// the thread backend's and, at `D = 0`, the pre-pipeline drivers'.
    fn prime(server: &mut dyn Master, n: usize, depth: usize, k: usize) -> PullWindow {
        let mut w = PullWindow {
            depth,
            k,
            bufs: (0..n).map(|_| VecDeque::with_capacity(depth + 1)).collect(),
        };
        for _ in 0..=depth {
            for slot in 0..n {
                w.pull_one(server, slot);
            }
        }
        w
    }

    fn pull_one(&mut self, server: &mut dyn Master, slot: usize) {
        let mut buf = vec![0.0f32; self.k];
        server.pull_into(slot, &mut buf);
        self.bufs[slot].push_back(buf);
    }

    /// A joiner primes its own window (all pulls at the join step).
    fn prime_slot(&mut self, server: &mut dyn Master, slot: usize) {
        if slot == self.bufs.len() {
            self.bufs.push(VecDeque::with_capacity(self.depth + 1));
        } else {
            self.bufs[slot].clear();
        }
        for _ in 0..=self.depth {
            self.pull_one(server, slot);
        }
    }

    /// The parameters worker `slot`'s completing batch was computed on.
    fn front(&self, slot: usize) -> &[f32] {
        self.bufs[slot].front().expect("pull window primed")
    }

    /// Consume the front (its batch just pushed) and issue the next pull
    /// into the recycled buffer — the allocation-free steady state.
    fn rotate(&mut self, server: &mut dyn Master, slot: usize) {
        let mut buf = self.bufs[slot].pop_front().expect("pull window primed");
        server.pull_into(slot, &mut buf);
        self.bufs[slot].push_back(buf);
    }

    fn retire(&mut self, slot: usize) {
        self.bufs[slot].clear();
    }
}

// ------------------------------------------------------------ sim-clock
// backend

/// Apply a membership event to the master and the worker-local state,
/// keeping the server's slot assignment in lockstep with the simulator's.
/// Returns the completion to process, if the event was one.
fn handle_event(
    server: &mut dyn Master,
    event: ClusterEvent,
    window: &mut PullWindow,
    wstate: &mut Vec<WorkerState>,
    compressor: &mut Compressor,
    policy: crate::optim::LeavePolicy,
    report: &mut TrainReport,
) -> anyhow::Result<Option<Completion>> {
    match event {
        ClusterEvent::Completion(c) => Ok(Some(c)),
        ClusterEvent::Join { worker, .. } => {
            let slot = server.add_worker();
            anyhow::ensure!(
                slot == worker,
                "membership drift: schedule assigned slot {worker}, server {slot}"
            );
            if slot == wstate.len() {
                wstate.push(server.make_worker_state());
            } else {
                wstate[slot] = server.make_worker_state();
            }
            // the joiner pulls (its whole window of) fresh parameters
            window.prime_slot(server, slot);
            // a reused slot must not inherit the leaver's error residual
            compressor.reset_slot(slot);
            report.workers_joined += 1;
            Ok(None)
        }
        ClusterEvent::Leave { worker, .. } => {
            server.remove_worker(worker, policy)?;
            window.retire(worker);
            compressor.reset_slot(worker);
            report.workers_left += 1;
            Ok(None)
        }
        // the schedule already rescaled the worker's execution-time model;
        // nothing changes master-side
        ClusterEvent::SpeedChange { .. } => Ok(None),
    }
}

/// The simulated-clock worker loop: cluster events from the gamma model,
/// gradients via `grad_step(worker, params, msg, want_loss)` (computed at
/// the window's *front* — the pull that batch was issued against), one
/// push + one window rotation per completion.  `eval` maps master
/// parameters to `(test loss, test error %)`.
pub fn run_sim<G, E>(
    cfg: &TrainConfig,
    theta0: &[f32],
    mut grad_step: G,
    mut eval: E,
) -> anyhow::Result<TrainReport>
where
    G: FnMut(usize, &[f32], &mut Vec<f32>, bool) -> anyhow::Result<f64>,
    E: FnMut(&[f32]) -> anyhow::Result<(f64, f64)>,
{
    let t0 = std::time::Instant::now();
    let n = cfg.n_workers;
    // in-process master, or a RemoteMaster against `--master tcp://...`
    let mut server = crate::net::master_for(cfg, theta0)?;
    server.metrics_mut().set_every(cfg.metrics_every);
    server.set_pipeline_depth(cfg.pipeline_depth);

    let total = cfg.total_master_steps();
    let mut cluster_rng = Rng::new(cfg.seed);
    let exec_model = ExecTimeModel::new(cfg.env, n, cfg.batch(), &mut cluster_rng);
    let mut schedule = AsyncSchedule::new(exec_model, cluster_rng.fork(1))
        .with_pipeline(cfg.pipeline_depth, cfg.rtt)
        .with_churn(&cfg.churn, total)?;

    // Worker-local state: the pipeline window of pulled parameters plus
    // optimizer state (DANA-Slim's momentum).
    let mut window = PullWindow::prime(server.as_mut(), n, cfg.pipeline_depth, theta0.len());
    let mut wstate: Vec<WorkerState> = (0..n).map(|_| server.make_worker_state()).collect();
    let mut compressor = in_process_compressor(cfg);

    let eval_every = eval_cadence(cfg);
    let loss_sample = loss_sample_every(total);

    let mut report = TrainReport {
        algorithm: cfg.algorithm.name().to_string(),
        n_workers: n,
        ..TrainReport::default()
    };

    let mut msg = vec![0.0f32; theta0.len()];
    let mut step: u64 = 0;
    while step < total {
        let event = schedule.next_event();
        let Some(c) = handle_event(
            server.as_mut(),
            event,
            &mut window,
            &mut wstate,
            &mut compressor,
            cfg.leave_policy,
            &mut report,
        )?
        else {
            continue;
        };
        let w = c.worker;
        // Worker w finished a batch it started earlier: compute the
        // message (gradient) at the parameters it pulled for that batch.
        let want_loss = step % loss_sample == 0;
        let loss = grad_step(w, window.front(w), &mut msg, want_loss)?;
        if want_loss {
            report.loss_curve.push((step, loss));
        }
        if !loss.is_finite() {
            report.diverged = true;
        }
        let s = server.step_now();
        server.worker_transform(&mut wstate[w], &mut msg, s);
        compressor.transform(w, &mut msg);
        server.push_update(w, &msg)?;
        // The pull for the batch `D + 1` ahead goes out with the push
        // (one combined round trip on a pipelined remote master).
        window.rotate(server.as_mut(), w);
        step += 1;

        if eval_every > 0 && step % eval_every == 0 {
            // settle deferred acks so the θ read observes every push
            server.drain_inflight()?;
            let (loss, err) = eval(&server.theta_vec())?;
            if !loss.is_finite() {
                report.diverged = true;
            }
            report.curve.push(EvalPoint {
                epoch: step as f64 / cfg.schedule.steps_per_epoch as f64,
                test_loss: loss,
                test_error: err,
                sim_time: schedule.now(),
            });
        }
    }

    server.drain_inflight()?;
    print_placement(server.as_mut());
    let (loss, err) = eval(&server.theta_vec())?;
    finish_eval(&mut report, loss, err);
    fold_metrics(&mut report, server.as_ref());
    // pushes the master layer itself lost (e.g. deferred acks a remote
    // reconnect abandoned) — invisible to the loop above, so fold them in
    report.pushes_dropped += server.pushes_lost();
    report.sim_time = schedule.now();
    report.steps = total;
    report.wall_secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

// ------------------------------------------------------------ thread
// backend

enum ToWorker {
    Params(Vec<f32>),
    Stop,
}

/// Worker→master messages, tagged with the slot's spawn generation so a
/// late message from a stopped incarnation cannot be misattributed to a
/// joiner that reused the slot.
enum FromWorker {
    Update { worker: usize, gen: u32, msg: Vec<f32>, loss: f32 },
    Exited { worker: usize, gen: u32, reason: String },
}

/// Best-effort message out of a caught panic payload.
fn panic_reason(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panicked".to_string()
    }
}

/// The real-thread worker loop: spawns one thread per initial worker (and
/// more on churn joins), each built by `make_step`, and runs the master
/// FIFO for `cfg.total_master_steps()` pushes.  The pipeline window is
/// the worker's channel: the master keeps `D + 1` parameter messages in
/// flight per worker (kickoff sends `D + 1`, then one per settled push),
/// and the worker consumes them FIFO — so its message for batch `n` is
/// computed at the pull issued after push `n − D − 1`, exactly like the
/// sim-clock backend.  `eval` maps master parameters to `(test loss,
/// test error %)`.
///
/// Public so external harnesses (the stress suite) can inject failing or
/// custom gradient sources without PJRT.
pub fn run_threads<F>(
    cfg: &TrainConfig,
    theta0: &[f32],
    make_step: &F,
    mut eval: impl FnMut(&[f32]) -> anyhow::Result<(f64, f64)>,
) -> anyhow::Result<TrainReport>
where
    F: Fn(usize) -> anyhow::Result<StepFn> + Sync,
{
    let t0 = std::time::Instant::now();
    let n = cfg.n_workers;
    cfg.churn.validate(n)?;
    let depth = cfg.pipeline_depth;
    // in-process master, or a RemoteMaster against `--master tcp://...`
    let mut server = crate::net::master_for(cfg, theta0)?;
    server.metrics_mut().set_every(cfg.metrics_every);
    server.set_pipeline_depth(depth);
    let rule = WorkerRule::for_algorithm(cfg.algorithm);
    let gamma = cfg.schedule.gamma;

    let (tx_master, rx_master) = mpsc::channel::<FromWorker>();

    let total = cfg.total_master_steps();
    let mut churn: VecDeque<(u64, ChurnAction)> = cfg.churn.thresholds(total).into();
    let mut churn_rng = Rng::new(cfg.seed ^ 0x454C_4153_5449_43); // random leave victims
    let mut report = TrainReport {
        algorithm: cfg.algorithm.name().to_string(),
        n_workers: n,
        ..TrainReport::default()
    };
    let eval_every = eval_cadence(cfg);
    let loss_sample = loss_sample_every(total);
    // Push-side compression lives on the master thread (the one place
    // every update already passes through), keeping the per-slot
    // error-feedback residuals single-threaded.
    let mut compressor = in_process_compressor(cfg);

    std::thread::scope(|scope| -> anyhow::Result<()> {
        // Spawn (or respawn) the worker thread for a slot; used at kick-off
        // and for mid-run joins.  `gen` tags every message the incarnation
        // sends.  Init/step failures AND panics are caught and reported as
        // `Exited` — a panicking gradient source must surface as a lost
        // worker, not hang the master's recv (the master keeps a sender
        // alive, so channel disconnection can never signal thread death).
        let spawn_worker = |w: usize, gen: u32| -> mpsc::Sender<ToWorker> {
            let (tx_w, rx_w) = mpsc::channel::<ToWorker>();
            let tx_master = tx_master.clone();
            scope.spawn(move || {
                let exit = |reason: String| {
                    let _ = tx_master.send(FromWorker::Exited { worker: w, gen, reason });
                };
                let init =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| make_step(w)));
                let mut step_fn = match init {
                    Ok(Ok(s)) => s,
                    Ok(Err(e)) => return exit(format!("init failed: {e}")),
                    Err(p) => return exit(format!("init panicked: {}", panic_reason(p))),
                };
                let mut v_local: Vec<f32> = vec![];
                loop {
                    match rx_w.recv() {
                        Ok(ToWorker::Params(params)) => {
                            let step = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || step_fn(&params),
                            ));
                            match step {
                                Ok(Ok((loss, mut msg))) => {
                                    rule.apply(&mut v_local, &mut msg, gamma);
                                    if tx_master
                                        .send(FromWorker::Update { worker: w, gen, msg, loss })
                                        .is_err()
                                    {
                                        return;
                                    }
                                }
                                Ok(Err(e)) => return exit(format!("step failed: {e}")),
                                Err(p) => {
                                    return exit(format!("step panicked: {}", panic_reason(p)))
                                }
                            }
                        }
                        // master-initiated stop (leave or end of run)
                        Ok(ToWorker::Stop) | Err(_) => return,
                    }
                }
            });
            tx_w
        };

        // `senders[w].is_some()` IS the thread-liveness state: a slot has a
        // sender exactly while its current incarnation may still produce
        // messages the master should honor.
        let mut senders: Vec<Option<mpsc::Sender<ToWorker>>> = Vec::with_capacity(n);
        let mut thread_gen: Vec<u32> = vec![0; n];
        // Crash-loop supervision budget, per slot: how many times this
        // slot's thread has been restarted after dying.
        let mut restarts: Vec<u32> = vec![0; n];
        for w in 0..n {
            senders.push(Some(spawn_worker(w, 0)));
        }
        // Kick off: every worker gets D+1 initial (pulled) parameter
        // messages — its pipeline window, queued in its channel.  Issued
        // round-robin so the pull order matches the sim backend's prime.
        for _ in 0..=depth {
            for (w, tx) in senders.iter().enumerate() {
                if let Some(tx) = tx {
                    tx.send(ToWorker::Params(server.pull_params(w))).ok();
                }
            }
        }

        let mut step: u64 = 0;
        while step < total {
            // Fire membership events due at this master step.
            while churn.front().is_some_and(|&(at, _)| step >= at) {
                let (_, action) = churn.pop_front().expect("front checked");
                match action {
                    ChurnAction::Join => {
                        let slot = server.add_worker();
                        if slot == senders.len() {
                            senders.push(None);
                            thread_gen.push(0);
                            restarts.push(0);
                        }
                        thread_gen[slot] = thread_gen[slot].wrapping_add(1);
                        let tx = spawn_worker(slot, thread_gen[slot]);
                        // the joiner primes its whole pipeline window
                        for _ in 0..=depth {
                            tx.send(ToWorker::Params(server.pull_params(slot))).ok();
                        }
                        senders[slot] = Some(tx);
                        // a reused slot must not inherit a leaver's residual
                        compressor.reset_slot(slot);
                        report.workers_joined += 1;
                    }
                    ChurnAction::Leave(who) => {
                        // A named worker may already be gone (it crashed and
                        // was retired as an implicit leave) and lost threads
                        // may leave nobody to evict — both are no-ops, not
                        // reasons to abort the surviving run.
                        let victim = match who {
                            Some(w) if server.is_live(w) => Some(w),
                            Some(w) => {
                                eprintln!("churn: skipping leave of worker {w} (already gone)");
                                None
                            }
                            None => {
                                let live: Vec<usize> = (0..server.workers())
                                    .filter(|&i| server.is_live(i))
                                    .collect();
                                if live.is_empty() {
                                    None
                                } else {
                                    Some(live[churn_rng.below(live.len() as u64) as usize])
                                }
                            }
                        };
                        if let Some(w) = victim {
                            server.remove_worker(w, cfg.leave_policy)?;
                            if let Some(tx) = senders[w].take() {
                                tx.send(ToWorker::Stop).ok();
                            }
                            compressor.reset_slot(w);
                            report.workers_left += 1;
                        }
                    }
                    // real threads run at hardware speed; straggler onset
                    // is only meaningful under the simulated clock
                    ChurnAction::SpeedChange(..) => {}
                }
            }

            // Fail fast: the FIFO cannot make progress once no live thread
            // remains to produce updates.
            anyhow::ensure!(
                senders.iter().any(Option::is_some),
                "no live workers left at master step {step}/{total} \
                 ({} lost, {} left); aborting instead of deadlocking",
                report.workers_lost,
                report.workers_left
            );

            match rx_master.recv().expect("master keeps a sender; recv cannot fail") {
                FromWorker::Exited { worker, gen, reason } => {
                    if gen != thread_gen[worker] || senders[worker].is_none() {
                        continue; // stale incarnation: already stopped/left
                    }
                    senders[worker] = None;
                    if restarts[worker] < cfg.max_restarts && server.is_live(worker) {
                        // Crash-loop supervision: restart the thread in
                        // place under a bounded exponential backoff.  The
                        // slot stays live, so the new incarnation inherits
                        // its momentum vᶦ — a restart is a hiccup, not a
                        // leave/join (no v⁰ fold, no α/τ retune).  It
                        // primes a fresh D+1 pull window exactly like a
                        // churn join; the dead incarnation's undelivered
                        // parameter messages died with its channel.
                        restarts[worker] += 1;
                        report.worker_restarts += 1;
                        let attempt = restarts[worker];
                        let backoff_ms = crate::util::backoff_ms(cfg.restart_backoff_ms, attempt);
                        eprintln!(
                            "worker {worker}: {reason}; restart {attempt}/{} after {backoff_ms} ms",
                            cfg.max_restarts
                        );
                        std::thread::sleep(std::time::Duration::from_millis(backoff_ms));
                        thread_gen[worker] = thread_gen[worker].wrapping_add(1);
                        let tx = spawn_worker(worker, thread_gen[worker]);
                        for _ in 0..=depth {
                            tx.send(ToWorker::Params(server.pull_params(worker))).ok();
                        }
                        senders[worker] = Some(tx);
                        // residuals are incarnation-local: abandoned with
                        // the dead thread, like a remote reconnect's
                        compressor.reset_slot(worker);
                    } else {
                        // Restart budget exhausted (or the slot is already
                        // retired): a dying worker is an implicit leave, so
                        // its momentum doesn't linger frozen in v⁰.
                        if server.is_live(worker) {
                            server.remove_worker(worker, cfg.leave_policy)?;
                        }
                        report.workers_lost += 1;
                        eprintln!("worker {worker}: {reason}");
                    }
                }
                FromWorker::Update { worker, gen, mut msg, loss } => {
                    if gen != thread_gen[worker] {
                        // late push from a stopped incarnation
                        report.pushes_dropped += 1;
                        continue;
                    }
                    if !server.is_live(worker) {
                        // in-flight push raced a leave: recoverable, drop it
                        report.pushes_dropped += 1;
                        continue;
                    }
                    // (a remote master may be shared with other clients,
                    // whose pushes legitimately advance it between ours)
                    debug_assert!(
                        cfg.master_addr.is_some() || server.steps_done() == step,
                        "master step not monotone"
                    );
                    if step % loss_sample == 0 {
                        report.loss_curve.push((step, loss as f64));
                    }
                    if !loss.is_finite() {
                        report.diverged = true;
                    }
                    compressor.transform(worker, &mut msg);
                    server.push_update(worker, &msg)?;
                    step += 1;
                    if step < total {
                        if let Some(tx) = &senders[worker] {
                            // round-trip buffer reuse: the worker's message
                            // buffer becomes its next parameter buffer
                            server.pull_into(worker, &mut msg);
                            tx.send(ToWorker::Params(msg)).ok();
                        }
                    }
                    if eval_every > 0 && step % eval_every == 0 {
                        server.drain_inflight()?;
                        let (l, e) = eval(&server.theta_vec())?;
                        report.curve.push(EvalPoint {
                            epoch: step as f64 / cfg.schedule.steps_per_epoch as f64,
                            test_loss: l,
                            test_error: e,
                            sim_time: t0.elapsed().as_secs_f64(),
                        });
                    }
                }
            }
        }
        // Stop every worker.  A pipelined worker may still hold up to D
        // queued parameter messages; the Stop queues behind them, so it
        // computes (and the master discards) at most that much overhang.
        for tx in senders.iter().flatten() {
            tx.send(ToWorker::Stop).ok();
        }
        Ok(())
    })?;

    server.drain_inflight()?;
    print_placement(server.as_mut());
    let (loss, err) = eval(&server.theta_vec())?;
    finish_eval(&mut report, loss, err);
    report.mean_gap = server.metrics().mean_gap();
    report.mean_lag = server.metrics().mean_lag();
    // pushes the master layer itself lost (e.g. deferred acks a remote
    // reconnect abandoned), on top of the driver-level drops counted above
    report.pushes_dropped += server.pushes_lost();
    report.steps = total;
    report.wall_secs = t0.elapsed().as_secs_f64();
    report.sim_time = report.wall_secs; // real time is the clock here
    Ok(report)
}

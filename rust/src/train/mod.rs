//! Training drivers: the unified pipelined asynchronous driver
//! ([`driver`], shared by the simulated-clock §5.1/§5.2 and real-thread
//! §5.4 backends), the synchronous SSGD baseline and the single-worker
//! baseline.

pub mod baseline;
pub mod data_source;
pub mod driver;
pub mod real_async;
pub mod sim_trainer;
pub mod ssgd;

pub use data_source::DataSource;

/// One point of the evaluation curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalPoint {
    pub epoch: f64,
    pub test_loss: f64,
    /// Test error in percent (100 - accuracy), the paper's y-axis.
    pub test_error: f64,
    /// Simulated time units elapsed (gamma model) at this point.
    pub sim_time: f64,
}

/// Result of one training run.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub algorithm: String,
    pub n_workers: usize,
    pub final_test_error: f64,
    pub final_test_loss: f64,
    pub curve: Vec<EvalPoint>,
    /// (master_step, train_loss) subsampled.
    pub loss_curve: Vec<(u64, f64)>,
    pub mean_gap: f64,
    pub mean_lag: f64,
    /// Gap trace (master_step, gap) when metrics were enabled.
    pub gap_curve: Vec<(u64, f64)>,
    /// Lag trace (master_step, worker, lag) when metrics were enabled —
    /// the per-push staleness histogram (`rust/tests/pipeline.rs` pins
    /// its exact +D shift under a pipelined driver).
    pub lag_curve: Vec<(u64, usize, u64)>,
    /// Normalized gap trace (Appendix B.3).
    pub norm_gap_curve: Vec<(u64, f64)>,
    /// Gradient-norm trace (Fig 11a).
    pub grad_norm_curve: Vec<(u64, f64)>,
    /// Total simulated time units (async/ssgd modes).
    pub sim_time: f64,
    /// Wall-clock seconds spent in the driver.
    pub wall_secs: f64,
    /// Master steps executed.
    pub steps: u64,
    /// True if any eval produced a non-finite loss (divergence guard).
    pub diverged: bool,
    /// Workers that joined mid-run (cluster churn events).
    pub workers_joined: usize,
    /// Workers that left mid-run (cluster churn events).
    pub workers_left: usize,
    /// Worker threads lost to init/step failures (real-thread driver);
    /// always 0 in the simulated drivers.  A worker only counts as lost
    /// once its crash-loop restart budget (`--max-restarts`) is spent.
    pub workers_lost: usize,
    /// Worker-thread restarts performed by the crash-loop supervisor
    /// (real-thread driver, `--max-restarts > 0`).
    pub worker_restarts: usize,
    /// Pushes the driver dropped instead of applying: late messages from
    /// stopped worker incarnations, in-flight pushes that raced a leave
    /// (real-thread backend; the simulated clock discards a leaver's
    /// batch before it is ever computed), and deferred-push acks a
    /// remote-master reconnect abandoned.  A remote server's own drop
    /// count travels in the wire `Status` header instead.
    pub pushes_dropped: u64,
}

impl TrainReport {
    /// Paper-style summary line; membership deltas are appended only when
    /// the cluster actually changed.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{:<11} N={:<3} err={:6.2}% loss={:8.4} gap={:.2e} lag={:5.1} simt={:.0} ({:.1}s)",
            self.algorithm,
            self.n_workers,
            self.final_test_error,
            self.final_test_loss,
            self.mean_gap,
            self.mean_lag,
            self.sim_time,
            self.wall_secs
        );
        if self.workers_joined + self.workers_left + self.workers_lost > 0 {
            s.push_str(&format!(
                " churn(+{}/-{}/!{})",
                self.workers_joined, self.workers_left, self.workers_lost
            ));
        }
        if self.worker_restarts > 0 {
            s.push_str(&format!(" restarts={}", self.worker_restarts));
        }
        if self.pushes_dropped > 0 {
            s.push_str(&format!(" dropped={}", self.pushes_dropped));
        }
        s
    }
}

//! Synchronous SGD baseline (§5.4's DistributedDataParallel stand-in):
//! N workers compute gradients on identical parameters, a barrier averages
//! them, one Nesterov step fires per round.  Simulated round time is the
//! slowest worker's gamma draw — the straggler penalty that Fig 12 and
//! Table 1 quantify.

use crate::config::TrainConfig;
use crate::optim::sgd::SyncSgd;
use crate::optim::LrSchedule;
use crate::runtime::Engine;
use crate::sim::{ExecTimeModel, SyncSchedule};
use crate::train::data_source::{evaluate, DataSource};
use crate::train::{EvalPoint, TrainReport};
use crate::util::rng::Rng;

/// Run SSGD for the same total batch budget as an async run of the same
/// config (`cfg.total_master_steps()` batches overall).
pub fn run(cfg: &TrainConfig, engine: &Engine) -> anyhow::Result<TrainReport> {
    let t0 = std::time::Instant::now();
    let model = engine.load_model(&cfg.variant_name())?;
    let theta0 = engine.init_params(&cfg.variant_name())?;
    let mut ds = DataSource::for_config(cfg);
    let eval_set = ds.eval_set();

    let n = cfg.n_workers;
    let schedule = LrSchedule::new(cfg.schedule.clone());
    let mut cluster_rng = Rng::new(cfg.seed);
    let exec_model = ExecTimeModel::new(cfg.env, n, cfg.batch(), &mut cluster_rng);
    let mut rounds_clock = SyncSchedule::new(exec_model, cluster_rng.fork(1));

    let mut sync = SyncSgd::new(&theta0, n);
    let total = cfg.total_master_steps();
    let rounds = (total as usize).div_ceil(n);
    let eval_every_rounds = if cfg.eval_every_epochs > 0.0 {
        ((cfg.eval_every_epochs * cfg.schedule.steps_per_epoch as f64) / n as f64).round() as usize
    } else {
        0
    }
    .max(if cfg.eval_every_epochs > 0.0 { 1 } else { 0 });
    let loss_sample = crate::train::driver::loss_sample_every(rounds as u64) as usize;

    let mut report = TrainReport {
        algorithm: "ssgd".to_string(),
        n_workers: n,
        ..TrainReport::default()
    };

    for round in 0..rounds {
        // LR indexed by consumed batches (round*n) so decay epochs line up
        // with the async runs.
        let s = schedule.step_at((round * n) as u64);
        let mut round_loss = 0.0;
        for _ in 0..n {
            let batch = ds.next_train();
            let (loss, grads) = model.train_step(sync.theta(), batch.input(), &batch.y)?;
            round_loss += loss as f64;
            sync.contribute(&grads, s.eta, s.gamma);
        }
        rounds_clock.next_round();
        if round % loss_sample == 0 {
            report.loss_curve.push(((round * n) as u64, round_loss / n as f64));
        }
        if eval_every_rounds > 0 && (round + 1) % eval_every_rounds == 0 {
            let (l, e) = evaluate(&model, sync.theta(), &eval_set)?;
            report.curve.push(EvalPoint {
                epoch: ((round + 1) * n) as f64 / cfg.schedule.steps_per_epoch as f64,
                test_loss: l,
                test_error: e,
                sim_time: rounds_clock.now(),
            });
        }
    }

    let (loss, err) = evaluate(&model, sync.theta(), &eval_set)?;
    crate::train::driver::finish_eval(&mut report, loss, err);
    report.sim_time = rounds_clock.now();
    report.steps = total;
    report.wall_secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

//! Simulated-clock asynchronous training — the paper's §5.1/§5.2 method.
//!
//! Worker completion times come from the gamma execution-time model (the
//! virtual cluster); the gradients themselves are *real*, computed by the
//! AOT-compiled model through PJRT.  Every algorithm trained under the same
//! seed sees the identical completion schedule and batch stream, which is
//! exactly the controlled comparison the paper runs ("all algorithms share
//! the same worker update schedules and therefore have an identical lag").
//!
//! The master is built through [`crate::net::master_for`]: `cfg.shards > 1`
//! runs the same experiment against the sharded, lock-striped server (the
//! equivalence suite guarantees an identical trajectory up to f32
//! reassociation), and [`crate::config::TrainConfig::master_addr`] runs it
//! against a remote `dana serve` master over TCP — bit-for-bit identical
//! over loopback (`rust/tests/net.rs`).
//!
//! The driver consumes *cluster events*, not just completions: a
//! [`TrainConfig::churn`] schedule splices joins, leaves and straggler
//! onsets into the run, and [`handle_event`] keeps the master's membership
//! in lockstep with the simulator's.  An empty churn schedule reproduces
//! the fixed-membership trajectories bit-for-bit (pinned by
//! `rust/tests/churn.rs`).
//!
//! [`run_synthetic`] is the PJRT-free variant over the seeded noisy
//! quadratic of [`super::real_async`] — the full master/schedule/churn
//! machinery with no artifacts, used by the churn experiment sweep and the
//! equivalence tests.

use crate::config::TrainConfig;
use crate::optim::{LeavePolicy, WorkerState};
use crate::runtime::Engine;
use crate::server::Master;
use crate::sim::{AsyncSchedule, ClusterEvent, Completion, ExecTimeModel};
use crate::train::data_source::{evaluate, DataSource};
use crate::train::{real_async, EvalPoint, TrainReport};
use crate::util::rng::Rng;

/// Apply a membership event to the master and the per-worker local state,
/// keeping the server's slot assignment in lockstep with the simulator's.
/// Returns the completion to process, if the event was one.
fn handle_event(
    server: &mut dyn Master,
    event: ClusterEvent,
    local: &mut Vec<Vec<f32>>,
    wstate: &mut Vec<WorkerState>,
    policy: LeavePolicy,
    report: &mut TrainReport,
) -> anyhow::Result<Option<Completion>> {
    match event {
        ClusterEvent::Completion(c) => Ok(Some(c)),
        ClusterEvent::Join { worker, .. } => {
            let slot = server.add_worker();
            anyhow::ensure!(
                slot == worker,
                "membership drift: schedule assigned slot {worker}, server {slot}"
            );
            if slot == local.len() {
                local.push(vec![0.0; server.param_len()]);
                wstate.push(server.make_worker_state());
            } else {
                wstate[slot] = server.make_worker_state();
            }
            // the joiner pulls fresh parameters for its first batch
            server.pull_into(slot, &mut local[slot]);
            report.workers_joined += 1;
            Ok(None)
        }
        ClusterEvent::Leave { worker, .. } => {
            server.remove_worker(worker, policy)?;
            report.workers_left += 1;
            Ok(None)
        }
        // the schedule already rescaled the worker's execution-time model;
        // nothing changes master-side
        ClusterEvent::SpeedChange { .. } => Ok(None),
    }
}

/// Seed perturbation for the synthetic gradient-noise stream (independent
/// of the cluster RNG streams, so the schedule is identical whatever the
/// gradient source).  Public so the churn equivalence suite can replicate
/// the stream in its pre-elastic reference driver.
pub const SYNTH_GRAD_STREAM: u64 = 0x5EED_6AAD;

/// The shared simulated-clock driver: cluster-event loop, membership
/// handling, metric/report plumbing — generic over the gradient source.
/// `grad_step(worker, params, msg, want_loss)` fills `msg` with the
/// worker's message computed at `params` and returns the train loss; when
/// `want_loss` is false the value is not recorded, so cheap sources may
/// return 0.0 without computing it.  `eval` maps master parameters to
/// `(test loss, test error %)` for the periodic and final evaluations.
///
/// Both [`run`] and [`run_synthetic`] drive THIS loop, which is what keeps
/// their trajectories in lockstep — the churn equivalence suite pins its
/// behavior bit-for-bit against the pre-elastic loop shape.
fn run_sim_core<G, E>(
    cfg: &TrainConfig,
    theta0: &[f32],
    mut grad_step: G,
    mut eval: E,
) -> anyhow::Result<TrainReport>
where
    G: FnMut(usize, &[f32], &mut Vec<f32>, bool) -> anyhow::Result<f64>,
    E: FnMut(&[f32]) -> anyhow::Result<(f64, f64)>,
{
    let t0 = std::time::Instant::now();
    let n = cfg.n_workers;
    // in-process master, or a RemoteMaster against `--master tcp://...`
    let mut server = crate::net::master_for(cfg, theta0)?;
    server.metrics_mut().set_every(cfg.metrics_every);

    let total = cfg.total_master_steps();
    let mut cluster_rng = Rng::new(cfg.seed);
    let exec_model = ExecTimeModel::new(cfg.env, n, cfg.batch(), &mut cluster_rng);
    let mut schedule =
        AsyncSchedule::new(exec_model, cluster_rng.fork(1)).with_churn(&cfg.churn, total)?;

    // Worker-local state: pulled parameters + optimizer state (DANA-Slim).
    // The locals are retained buffers, so seed them through the
    // `pull_into` reuse path like every later pull (no `pull_params`
    // double-copy in the loop).
    let mut local: Vec<Vec<f32>> = Vec::with_capacity(n);
    let mut wstate: Vec<WorkerState> = Vec::with_capacity(n);
    for w in 0..n {
        let mut buf = vec![0.0f32; theta0.len()];
        server.pull_into(w, &mut buf);
        local.push(buf);
        wstate.push(server.make_worker_state());
    }

    let eval_every = if cfg.eval_every_epochs > 0.0 {
        (cfg.eval_every_epochs * cfg.schedule.steps_per_epoch as f64).round() as u64
    } else {
        0
    };
    let loss_sample = (total / 200).max(1);

    let mut report = TrainReport {
        algorithm: cfg.algorithm.name().to_string(),
        n_workers: n,
        ..TrainReport::default()
    };

    let mut msg = vec![0.0f32; theta0.len()];
    let mut step: u64 = 0;
    while step < total {
        let event = schedule.next_event();
        let Some(c) = handle_event(
            server.as_mut(),
            event,
            &mut local,
            &mut wstate,
            cfg.leave_policy,
            &mut report,
        )?
        else {
            continue;
        };
        let w = c.worker;
        // Worker w finished a batch it started earlier: compute the
        // message (gradient) at the parameters it pulled.
        let want_loss = step % loss_sample == 0;
        let loss = grad_step(w, &local[w], &mut msg, want_loss)?;
        if want_loss {
            report.loss_curve.push((step, loss));
        }
        if !loss.is_finite() {
            report.diverged = true;
        }
        let s = server.step_now();
        server.worker_transform(&mut wstate[w], &mut msg, s);
        server.push_update(w, &msg)?;
        // Immediately pull fresh parameters for the next batch (into the
        // retained per-worker buffer — no per-step allocation).
        server.pull_into(w, &mut local[w]);
        step += 1;

        if eval_every > 0 && step % eval_every == 0 {
            let (loss, err) = eval(&server.theta_vec())?;
            if !loss.is_finite() {
                report.diverged = true;
            }
            report.curve.push(EvalPoint {
                epoch: step as f64 / cfg.schedule.steps_per_epoch as f64,
                test_loss: loss,
                test_error: err,
                sim_time: schedule.now(),
            });
        }
    }

    let (loss, err) = eval(&server.theta_vec())?;
    report.final_test_loss = loss;
    report.final_test_error = err;
    if !loss.is_finite() {
        report.diverged = true;
        // Paper convention: a diverged run scores chance accuracy.
        report.final_test_error = 100.0;
    }
    finish_report(&mut report, server.as_ref(), &schedule, total, t0);
    Ok(report)
}

/// Run one simulated asynchronous training experiment (real gradients
/// through PJRT).
pub fn run(cfg: &TrainConfig, engine: &Engine) -> anyhow::Result<TrainReport> {
    let model = engine.load_model(&cfg.variant_name())?;
    let theta0 = engine.init_params(&cfg.variant_name())?;
    let mut ds = DataSource::for_config(cfg);
    let eval_set = ds.eval_set();
    run_sim_core(
        cfg,
        &theta0,
        |_w, params, msg: &mut Vec<f32>, _want_loss| {
            // the train loss is a free byproduct here, so want_loss is moot
            let batch = ds.next_train();
            let (loss, g) = model.train_step(params, batch.input(), &batch.y)?;
            *msg = g;
            Ok(loss as f64)
        },
        |theta| evaluate(&model, theta, &eval_set),
    )
}

/// Simulated-clock training on the seeded noisy quadratic — no PJRT, no
/// artifacts.  The schedule (and its churn events) is identical to what
/// [`run`] would see under the same config; gradients come from the
/// synthetic objective of [`real_async`].  This is the artifact-free
/// workload behind `dana experiment churn` and the churn equivalence
/// suite.
pub fn run_synthetic(cfg: &TrainConfig, k: usize) -> anyhow::Result<TrainReport> {
    anyhow::ensure!(k > 0, "synthetic workload needs k > 0");
    let curv = real_async::synthetic_curvature(k);
    let grad_curv = curv.clone();
    let mut grad_rng = Rng::new(cfg.seed ^ SYNTH_GRAD_STREAM);
    run_sim_core(
        cfg,
        &real_async::synthetic_theta0(k),
        move |_w, params, msg: &mut Vec<f32>, want_loss| {
            real_async::synthetic_grad(params, &grad_curv, &mut grad_rng, msg);
            // the loss costs another O(k) pass here, so honor want_loss
            Ok(if want_loss {
                real_async::synthetic_loss(params, &grad_curv)
            } else {
                0.0
            })
        },
        move |theta| Ok(real_async::synthetic_eval(theta, &curv)),
    )
}

/// Fold the server's metric taps and the schedule clock into the report.
fn finish_report(
    report: &mut TrainReport,
    server: &dyn Master,
    schedule: &AsyncSchedule,
    total: u64,
    t0: std::time::Instant,
) {
    report.mean_gap = server.metrics().mean_gap();
    report.mean_lag = server.metrics().mean_lag();
    for r in server.metrics().rows() {
        report.gap_curve.push((r.step, r.gap));
        report.norm_gap_curve.push((r.step, r.norm_gap));
        report.grad_norm_curve.push((r.step, r.msg_norm));
    }
    report.sim_time = schedule.now();
    report.steps = total;
    report.wall_secs = t0.elapsed().as_secs_f64();
}

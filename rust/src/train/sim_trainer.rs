//! Simulated-clock asynchronous training — the paper's §5.1/§5.2 method.
//!
//! Worker completion times come from the gamma execution-time model (the
//! virtual cluster); the gradients themselves are *real*, computed by the
//! AOT-compiled model through PJRT.  Every algorithm trained under the same
//! seed sees the identical completion schedule and batch stream, which is
//! exactly the controlled comparison the paper runs ("all algorithms share
//! the same worker update schedules and therefore have an identical lag").
//!
//! Since the pipelined-runtime refactor this module is a thin shim: the
//! actual worker loop — cluster events, membership handling, the pipeline
//! window (`--pipeline-depth`), metric/report plumbing — is
//! [`super::driver::run_sim`], shared with the real-thread backend.  The
//! master is built through [`crate::net::master_for`]: `cfg.shards > 1`
//! runs the same experiment against the sharded, lock-striped server and
//! [`crate::config::TrainConfig::master_addr`] runs it against a remote
//! `dana serve` master over TCP — bit-for-bit identical over loopback
//! (`rust/tests/net.rs`), with `--pipeline-depth ≥ 1` switching pushes to
//! the deferred-ack send path.
//!
//! An empty churn schedule and depth 0 reproduce the pre-elastic,
//! pre-pipeline trajectories bit-for-bit (pinned by `rust/tests/churn.rs`
//! and `rust/tests/pipeline.rs`).
//!
//! [`run_synthetic`] is the PJRT-free variant over the seeded noisy
//! quadratic — the full master/schedule/churn machinery with no
//! artifacts, used by the experiment sweeps and the equivalence tests.

use crate::config::TrainConfig;
use crate::runtime::Engine;
use crate::train::data_source::{evaluate, DataSource};
use crate::train::driver::{self, WorkerBackend};
use crate::train::TrainReport;

/// Seed perturbation for the synthetic gradient-noise stream (independent
/// of the cluster RNG streams, so the schedule is identical whatever the
/// gradient source).  Public so the churn equivalence suite can replicate
/// the stream in its pre-elastic reference driver.
pub const SYNTH_GRAD_STREAM: u64 = 0x5EED_6AAD;

/// Run one simulated asynchronous training experiment (real gradients
/// through PJRT).
pub fn run(cfg: &TrainConfig, engine: &Engine) -> anyhow::Result<TrainReport> {
    let model = engine.load_model(&cfg.variant_name())?;
    let theta0 = engine.init_params(&cfg.variant_name())?;
    let mut ds = DataSource::for_config(cfg);
    let eval_set = ds.eval_set();
    driver::run_sim(
        cfg,
        &theta0,
        |_w, params, msg: &mut Vec<f32>, _want_loss| {
            // the train loss is a free byproduct here, so want_loss is moot
            let batch = ds.next_train();
            let (loss, g) = model.train_step(params, batch.input(), &batch.y)?;
            *msg = g;
            Ok(loss as f64)
        },
        |theta| evaluate(&model, theta, &eval_set),
    )
}

/// Simulated-clock training on the seeded noisy quadratic — no PJRT, no
/// artifacts.  The schedule (and its churn/pipeline events) is identical
/// to what [`run`] would see under the same config; gradients come from
/// the synthetic objective of [`crate::train::real_async`].
pub fn run_synthetic(cfg: &TrainConfig, k: usize) -> anyhow::Result<TrainReport> {
    driver::run_synthetic(cfg, k, WorkerBackend::SimClock)
}

//! Simulated-clock asynchronous training — the paper's §5.1/§5.2 method.
//!
//! Worker completion times come from the gamma execution-time model (the
//! virtual cluster); the gradients themselves are *real*, computed by the
//! AOT-compiled model through PJRT.  Every algorithm trained under the same
//! seed sees the identical completion schedule and batch stream, which is
//! exactly the controlled comparison the paper runs ("all algorithms share
//! the same worker update schedules and therefore have an identical lag").
//!
//! The master is built through [`make_master`], so `cfg.shards > 1` runs
//! the same experiment against the sharded, lock-striped server — the
//! equivalence suite guarantees an identical trajectory up to f32
//! reassociation.

use crate::config::TrainConfig;
use crate::optim::LrSchedule;
use crate::runtime::Engine;
use crate::server::make_master;
use crate::sim::{AsyncSchedule, ExecTimeModel};
use crate::train::data_source::{evaluate, DataSource};
use crate::train::{EvalPoint, TrainReport};
use crate::util::rng::Rng;

/// Run one simulated asynchronous training experiment.
pub fn run(cfg: &TrainConfig, engine: &Engine) -> anyhow::Result<TrainReport> {
    let t0 = std::time::Instant::now();
    let model = engine.load_model(&cfg.variant_name())?;
    let theta0 = engine.init_params(&cfg.variant_name())?;
    let mut ds = DataSource::for_config(cfg);
    let eval_set = ds.eval_set();

    let n = cfg.n_workers;
    let mut server = make_master(
        cfg.algorithm,
        &theta0,
        LrSchedule::new(cfg.schedule.clone()),
        n,
        cfg.shards,
        crate::util::parallel::default_threads(),
    );
    server.metrics_mut().set_every(cfg.metrics_every);

    let mut cluster_rng = Rng::new(cfg.seed);
    let exec_model = ExecTimeModel::new(cfg.env, n, cfg.batch(), &mut cluster_rng);
    let mut schedule = AsyncSchedule::new(exec_model, cluster_rng.fork(1));

    // Worker-local state: pulled parameters + optimizer state (DANA-Slim).
    let mut local: Vec<Vec<f32>> = Vec::with_capacity(n);
    let mut wstate: Vec<_> = Vec::with_capacity(n);
    for w in 0..n {
        local.push(server.pull_params(w));
        wstate.push(server.make_worker_state());
    }

    let total = cfg.total_master_steps();
    let eval_every = if cfg.eval_every_epochs > 0.0 {
        (cfg.eval_every_epochs * cfg.schedule.steps_per_epoch as f64).round() as u64
    } else {
        0
    };
    let loss_sample = (total / 200).max(1);

    let mut report = TrainReport {
        algorithm: cfg.algorithm.name().to_string(),
        n_workers: n,
        ..TrainReport::default()
    };

    for step in 0..total {
        let c = schedule.next_completion();
        let w = c.worker;
        // Worker w finished a batch it started earlier: compute the real
        // gradient at the parameters it pulled.
        let batch = ds.next_train();
        let (loss, mut msg) = model.train_step(&local[w], batch.input(), &batch.y)?;
        if step % loss_sample == 0 {
            report.loss_curve.push((step, loss as f64));
        }
        if !loss.is_finite() {
            report.diverged = true;
        }
        let s = server.step_now();
        server.worker_transform(&mut wstate[w], &mut msg, s);
        server.push_update(w, &msg);
        // Immediately pull fresh parameters for the next batch (into the
        // retained per-worker buffer — no per-step allocation).
        server.pull_into(w, &mut local[w]);

        if eval_every > 0 && (step + 1) % eval_every == 0 {
            let (loss, err) = evaluate(&model, &server.theta_vec(), &eval_set)?;
            if !loss.is_finite() {
                report.diverged = true;
            }
            report.curve.push(EvalPoint {
                epoch: (step + 1) as f64 / cfg.schedule.steps_per_epoch as f64,
                test_loss: loss,
                test_error: err,
                sim_time: schedule.now(),
            });
        }
    }

    let (loss, err) = evaluate(&model, &server.theta_vec(), &eval_set)?;
    report.final_test_loss = loss;
    report.final_test_error = err;
    if !loss.is_finite() {
        report.diverged = true;
        // Paper convention: a diverged run scores chance accuracy.
        report.final_test_error = 100.0;
    }
    report.mean_gap = server.metrics().mean_gap();
    report.mean_lag = server.metrics().mean_lag();
    for r in server.metrics().rows() {
        report.gap_curve.push((r.step, r.gap));
        report.norm_gap_curve.push((r.step, r.norm_gap));
        report.grad_norm_curve.push((r.step, r.msg_norm));
    }
    report.sim_time = schedule.now();
    report.steps = total;
    report.wall_secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

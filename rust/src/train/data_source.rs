//! Workload adapter: one interface over the classification proxies and the
//! char-LM corpus, shaped to a concrete AOT variant.

use crate::config::{TrainConfig, Workload};
use crate::data::synth::{Batcher, SynthDataset, SynthSpec};
use crate::data::text::CharCorpus;
use crate::runtime::{Input, Model};
use crate::util::rng::Rng;

/// An owned input batch matching a variant's x dtype.
#[derive(Debug, Clone)]
pub struct OwnedBatch {
    x_f32: Vec<f32>,
    x_i32: Vec<i32>,
    pub y: Vec<i32>,
    /// Number of label positions (B for MLP, B*T for the LM) — the
    /// denominator for accuracy.
    pub label_count: usize,
}

impl OwnedBatch {
    pub fn input(&self) -> Input<'_> {
        if self.x_f32.is_empty() {
            Input::I32(&self.x_i32)
        } else {
            Input::F32(&self.x_f32)
        }
    }
}

/// Deterministic train/test stream for one workload.
pub enum DataSource {
    Synth {
        data: SynthDataset,
        batcher: Batcher,
        batch: usize,
    },
    Text {
        corpus: CharCorpus,
        rng: Rng,
        batch: usize,
        seq: usize,
        eval: Vec<OwnedBatch>,
    },
}

impl DataSource {
    /// Build the workload's data stream (generation is seeded by the
    /// config: every algorithm sees the identical batch sequence).
    pub fn for_config(cfg: &TrainConfig) -> DataSource {
        let batch = cfg.batch();
        match cfg.workload {
            Workload::C10 | Workload::WrnC10 | Workload::C100 | Workload::ImageNet => {
                let spec = match cfg.workload {
                    // WRN-C10 is the same dataset as C10 — only the student
                    // architecture differs (as in the paper's panels).
                    Workload::C10 | Workload::WrnC10 => SynthSpec::c10(),
                    Workload::C100 => SynthSpec::c100(),
                    _ => SynthSpec::imagenet(),
                };
                let data = SynthDataset::generate(spec);
                let batcher = Batcher::new(data.train_size(), batch, cfg.seed ^ 0xBA7C);
                DataSource::Synth { data, batcher, batch }
            }
            Workload::LmSmall => {
                let corpus = CharCorpus::generate(64, 200_000, 0x7E47);
                let seq = 64;
                let eval = corpus
                    .eval_batches(8, batch, seq)
                    .into_iter()
                    .map(|tb| OwnedBatch {
                        x_f32: vec![],
                        x_i32: tb.x,
                        y: tb.y,
                        label_count: batch * seq,
                    })
                    .collect();
                DataSource::Text {
                    corpus,
                    rng: Rng::new(cfg.seed ^ 0x7397),
                    batch,
                    seq,
                    eval,
                }
            }
        }
    }

    /// Next training batch.
    pub fn next_train(&mut self) -> OwnedBatch {
        match self {
            DataSource::Synth { data, batcher, batch } => {
                let idx = batcher.next_indices();
                let b = data.train_batch(&idx);
                OwnedBatch { x_f32: b.x, x_i32: vec![], y: b.y, label_count: *batch }
            }
            DataSource::Text { corpus, rng, batch, seq, .. } => {
                let tb = corpus.sample_batch(*batch, *seq, rng);
                OwnedBatch {
                    x_f32: vec![],
                    x_i32: tb.x,
                    y: tb.y,
                    label_count: *batch * *seq,
                }
            }
        }
    }

    /// Fixed evaluation batches.
    pub fn eval_set(&self) -> Vec<OwnedBatch> {
        match self {
            DataSource::Synth { data, batch, .. } => data
                .test_batches(*batch)
                .into_iter()
                .map(|b| OwnedBatch {
                    x_f32: b.x,
                    x_i32: vec![],
                    y: b.y,
                    label_count: *batch,
                })
                .collect(),
            DataSource::Text { eval, .. } => eval.clone(),
        }
    }
}

/// Mean test loss + error(%) of `theta` over an eval set.
pub fn evaluate(model: &Model, theta: &[f32], eval_set: &[OwnedBatch]) -> anyhow::Result<(f64, f64)> {
    let mut loss_sum = 0.0;
    let mut correct = 0.0;
    let mut labels = 0usize;
    for b in eval_set {
        let (loss, corr) = model.eval_step(theta, b.input(), &b.y)?;
        loss_sum += loss as f64;
        correct += corr as f64;
        labels += b.label_count;
    }
    let mean_loss = loss_sum / eval_set.len() as f64;
    let err = 100.0 * (1.0 - correct / labels as f64);
    Ok((mean_loss, err))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::AlgorithmKind;

    #[test]
    fn synth_batches_have_right_shape() {
        let cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 4, 2.0);
        let mut ds = DataSource::for_config(&cfg);
        let b = ds.next_train();
        assert_eq!(b.y.len(), 128);
        assert_eq!(b.x_f32.len(), 128 * 128);
        assert!(matches!(b.input(), Input::F32(_)));
    }

    #[test]
    fn lm_batches_have_right_shape() {
        let cfg = TrainConfig::preset(Workload::LmSmall, AlgorithmKind::DanaSlim, 4, 1.0);
        let mut ds = DataSource::for_config(&cfg);
        let b = ds.next_train();
        assert_eq!(b.y.len(), 16 * 64);
        assert!(matches!(b.input(), Input::I32(_)));
        assert_eq!(ds.eval_set().len(), 8);
    }

    #[test]
    fn same_seed_same_stream() {
        let cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 4, 2.0);
        let mut a = DataSource::for_config(&cfg);
        let mut b = DataSource::for_config(&cfg);
        assert_eq!(a.next_train().y, b.next_train().y);
    }
}

//! Real-thread asynchronous training — the §5.4 setup scaled to this host.
//!
//! Every worker is an OS thread with its **own** gradient source; the
//! master thread owns the parameter server (monolithic or sharded per
//! `cfg.shards`, or a [`crate::net::RemoteMaster`] against
//! `cfg.master_addr`) and serves a plain FIFO over an mpsc channel.  Since
//! the pipelined-runtime refactor the loop itself lives in
//! [`super::driver::run_threads`], shared with the simulated-clock
//! backend: on every settled push the master replies with freshly pulled
//! parameters, and `--pipeline-depth D` keeps `D + 1` parameter messages
//! in flight per worker (the worker's channel IS its pipeline window), so
//! compute overlaps the master round trip — exactly the
//! pull→compute→push cycle of Algorithm 1 at `D = 0`, bit for bit.
//!
//! This module keeps the worker-side halves and the PJRT/synthetic
//! wiring:
//!
//! * [`run`] wires a PJRT client + compiled executable per worker thread
//!   (the `xla` wrapper types are not `Send`, and separate clients avoid
//!   any contention on the execution path — the analogue of one process
//!   per GPU in the paper's Fig 8);
//! * [`run_synthetic`] wires a seeded noisy quadratic objective — the
//!   deterministic concurrency stress harness used by `rust/tests/stress.rs`;
//! * [`WorkerRule`] — the worker-side optimizer transform (DANA-Slim's
//!   momentum), replicated per thread: state never crosses the channel,
//!   matching the paper's "completely eliminates the overhead at the
//!   master".
//!
//! Failure semantics (unchanged by the refactor): worker init/step errors
//! *and panics* surface as lost workers ([`crate::train::TrainReport::workers_lost`]),
//! late pushes from stopped incarnations and leave races are counted in
//! [`crate::train::TrainReport::pushes_dropped`], and the driver fails
//! fast when no live thread remains.

use crate::config::TrainConfig;
use crate::math;
use crate::optim::AlgorithmKind;
use crate::runtime::Engine;
use crate::train::data_source::{evaluate, DataSource};
use crate::train::driver::{self, WorkerBackend};
use crate::train::TrainReport;
use crate::util::rng::Rng;

/// Worker-side message transform, replicated per thread.
#[derive(Debug, Clone, Copy)]
pub enum WorkerRule {
    /// Send the raw gradient.
    Passthrough,
    /// DANA-Slim: keep momentum locally, send `gamma*v_new + g`.
    Slim,
}

impl WorkerRule {
    pub fn for_algorithm(kind: AlgorithmKind) -> WorkerRule {
        match kind {
            AlgorithmKind::DanaSlim => WorkerRule::Slim,
            _ => WorkerRule::Passthrough,
        }
    }

    pub(crate) fn apply(self, v: &mut Vec<f32>, grad: &mut [f32], gamma: f32) {
        match self {
            WorkerRule::Passthrough => {}
            WorkerRule::Slim => {
                if v.len() != grad.len() {
                    *v = vec![0.0; grad.len()];
                }
                // in place over the gradient buffer — no per-step scratch
                math::slim_worker_update_inplace(v, grad, gamma);
            }
        }
    }
}

/// Per-thread gradient source: `params -> (train loss, message)`.
/// Created *inside* the worker thread (so it may hold non-`Send` handles
/// like a PJRT client) and never crosses threads.
pub type StepFn = Box<dyn FnMut(&[f32]) -> anyhow::Result<(f32, Vec<f32>)>>;

/// Run real-thread asynchronous training against the AOT/PJRT runtime.
pub fn run(cfg: &TrainConfig, engine: &Engine) -> anyhow::Result<TrainReport> {
    let variant = cfg.variant_name().to_string();
    let theta0 = engine.init_params(&variant)?;
    let model = engine.load_model(&variant)?; // master's eval copy
    let eval_set = DataSource::for_config(cfg).eval_set();
    let artifacts = cfg.artifacts_dir.clone();
    let worker_cfg = cfg.clone();
    let make_step = move |w: usize| -> anyhow::Result<StepFn> {
        // Each worker owns a full engine: client + executable.
        let engine = Engine::cpu(&artifacts)?;
        let model = engine.load_model(&variant)?;
        let mut wcfg = worker_cfg.clone();
        wcfg.seed = worker_cfg.seed.wrapping_add(w as u64 * 7919);
        let mut ds = DataSource::for_config(&wcfg);
        Ok(Box::new(move |params: &[f32]| {
            // keep the client alive for the executable's whole lifetime
            let _ = &engine;
            let batch = ds.next_train();
            model.train_step(params, batch.input(), &batch.y)
        }) as StepFn)
    };
    run_core(cfg, &theta0, &make_step, |theta| {
        evaluate(&model, theta, &eval_set)
    })
}

/// Deterministic starting point for the synthetic objective.
pub fn synthetic_theta0(k: usize) -> Vec<f32> {
    (0..k).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect()
}

/// Per-coordinate curvatures of the synthetic quadratic (spread over a
/// 4x condition range so momentum actually matters).
pub fn synthetic_curvature(k: usize) -> Vec<f32> {
    (0..k).map(|i| 0.25 + 0.5 * ((i % 8) as f32) / 8.0).collect()
}

/// Mean quadratic loss `J(θ) = ½·mean(cᵢ·θᵢ²)` of the synthetic objective.
pub fn synthetic_loss(theta: &[f32], curv: &[f32]) -> f64 {
    let mut loss = 0.0f64;
    for (&t, &c) in theta.iter().zip(curv) {
        loss += 0.5 * c as f64 * t as f64 * t as f64;
    }
    loss / theta.len().max(1) as f64
}

/// One noisy gradient draw of the synthetic objective:
/// `out = curv ⊙ params + 0.01·N(0,1)` — the single definition every
/// synthetic driver and test harness shares.
pub fn synthetic_grad(params: &[f32], curv: &[f32], rng: &mut Rng, out: &mut [f32]) {
    for ((g, &p), &c) in out.iter_mut().zip(params).zip(curv) {
        *g = c * p + 0.01 * rng.normal() as f32;
    }
}

/// The per-worker noise stream of the synthetic objective.
pub fn synthetic_worker_rng(seed: u64, w: usize) -> Rng {
    Rng::new(seed ^ (w as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15))
}

/// `(test loss, bounded % error proxy)` of the synthetic objective.
pub fn synthetic_eval(theta: &[f32], curv: &[f32]) -> (f64, f64) {
    let loss = synthetic_loss(theta, curv);
    (loss, 100.0 * loss / (1.0 + loss))
}

/// Run real-thread asynchronous training on a seeded noisy quadratic —
/// no PJRT, no artifacts.  Exercises the full channel/threading/server
/// machinery; the reported test loss is [`synthetic_loss`] at the master
/// parameters (test error is a bounded percent proxy of the same).
pub fn run_synthetic(cfg: &TrainConfig, k: usize) -> anyhow::Result<TrainReport> {
    driver::run_synthetic(cfg, k, WorkerBackend::Threads)
}

/// The generic real-thread driver — a shim over
/// [`super::driver::run_threads`], kept under its historical name so
/// external harnesses (the stress suite) keep injecting failing or
/// custom gradient sources without PJRT.
pub fn run_core<F>(
    cfg: &TrainConfig,
    theta0: &[f32],
    make_step: &F,
    eval: impl FnMut(&[f32]) -> anyhow::Result<(f64, f64)>,
) -> anyhow::Result<TrainReport>
where
    F: Fn(usize) -> anyhow::Result<StepFn> + Sync,
{
    driver::run_threads(cfg, theta0, make_step, eval)
}

//! Real-thread asynchronous training — the §5.4 setup scaled to this host.
//!
//! Every worker is an OS thread with its **own** gradient source; the
//! master thread owns the parameter server (monolithic or sharded, per
//! `cfg.shards`) and serves a plain FIFO over an mpsc channel; on every
//! push it replies with freshly pulled parameters, exactly the
//! pull→compute→push cycle of Algorithm 1.
//!
//! The driver is split from the gradient computation so the concurrency
//! machinery is testable without PJRT:
//!
//! * [`run`] wires a PJRT client + compiled executable per worker thread
//!   (the `xla` wrapper types are not `Send`, and separate clients avoid
//!   any contention on the execution path — the analogue of one process
//!   per GPU in the paper's Fig 8);
//! * [`run_synthetic`] wires a seeded noisy quadratic objective — the
//!   deterministic concurrency stress harness used by `rust/tests/stress.rs`.
//!
//! The worker-side optimizer transform (DANA-Slim's momentum) runs inside
//! the worker thread via [`WorkerRule`] — state never crosses the channel,
//! matching the paper's "completely eliminates the overhead at the master".

use crate::config::TrainConfig;
use crate::math;
use crate::optim::{AlgorithmKind, LrSchedule};
use crate::runtime::Engine;
use crate::server::make_master;
use crate::train::data_source::{evaluate, DataSource};
use crate::train::{EvalPoint, TrainReport};
use crate::util::rng::Rng;
use std::sync::mpsc;

/// Worker-side message transform, replicated per thread.
#[derive(Debug, Clone, Copy)]
pub enum WorkerRule {
    /// Send the raw gradient.
    Passthrough,
    /// DANA-Slim: keep momentum locally, send `gamma*v_new + g`.
    Slim,
}

impl WorkerRule {
    pub fn for_algorithm(kind: AlgorithmKind) -> WorkerRule {
        match kind {
            AlgorithmKind::DanaSlim => WorkerRule::Slim,
            _ => WorkerRule::Passthrough,
        }
    }

    fn apply(self, v: &mut Vec<f32>, grad: &mut [f32], gamma: f32) {
        match self {
            WorkerRule::Passthrough => {}
            WorkerRule::Slim => {
                if v.len() != grad.len() {
                    *v = vec![0.0; grad.len()];
                }
                let mut send = vec![0.0f32; grad.len()];
                math::slim_worker_update(&mut send, v, grad, gamma);
                grad.copy_from_slice(&send);
            }
        }
    }
}

/// Per-thread gradient source: `params -> (train loss, message)`.
/// Created *inside* the worker thread (so it may hold non-`Send` handles
/// like a PJRT client) and never crosses threads.
pub type StepFn = Box<dyn FnMut(&[f32]) -> anyhow::Result<(f32, Vec<f32>)>>;

enum ToWorker {
    Params(Vec<f32>),
    Stop,
}

struct FromWorker {
    worker: usize,
    msg: Vec<f32>,
    loss: f32,
}

/// Run real-thread asynchronous training against the AOT/PJRT runtime.
pub fn run(cfg: &TrainConfig, engine: &Engine) -> anyhow::Result<TrainReport> {
    let variant = cfg.variant_name().to_string();
    let theta0 = engine.init_params(&variant)?;
    let model = engine.load_model(&variant)?; // master's eval copy
    let eval_set = DataSource::for_config(cfg).eval_set();
    let artifacts = cfg.artifacts_dir.clone();
    let worker_cfg = cfg.clone();
    let make_step = move |w: usize| -> anyhow::Result<StepFn> {
        // Each worker owns a full engine: client + executable.
        let engine = Engine::cpu(&artifacts)?;
        let model = engine.load_model(&variant)?;
        let mut wcfg = worker_cfg.clone();
        wcfg.seed = worker_cfg.seed.wrapping_add(w as u64 * 7919);
        let mut ds = DataSource::for_config(&wcfg);
        Ok(Box::new(move |params: &[f32]| {
            // keep the client alive for the executable's whole lifetime
            let _ = &engine;
            let batch = ds.next_train();
            model.train_step(params, batch.input(), &batch.y)
        }) as StepFn)
    };
    run_core(cfg, &theta0, &make_step, |theta| {
        evaluate(&model, theta, &eval_set)
    })
}

/// Deterministic starting point for the synthetic objective.
pub fn synthetic_theta0(k: usize) -> Vec<f32> {
    (0..k).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect()
}

/// Per-coordinate curvatures of the synthetic quadratic (spread over a
/// 4x condition range so momentum actually matters).
pub fn synthetic_curvature(k: usize) -> Vec<f32> {
    (0..k).map(|i| 0.25 + 0.5 * ((i % 8) as f32) / 8.0).collect()
}

/// Mean quadratic loss `J(θ) = ½·mean(cᵢ·θᵢ²)` of the synthetic objective.
pub fn synthetic_loss(theta: &[f32], curv: &[f32]) -> f64 {
    let mut loss = 0.0f64;
    for (&t, &c) in theta.iter().zip(curv) {
        loss += 0.5 * c as f64 * t as f64 * t as f64;
    }
    loss / theta.len().max(1) as f64
}

/// Run real-thread asynchronous training on a seeded noisy quadratic —
/// no PJRT, no artifacts.  Exercises the full channel/threading/server
/// machinery; the reported test loss is [`synthetic_loss`] at the master
/// parameters (test error is a bounded percent proxy of the same).
pub fn run_synthetic(cfg: &TrainConfig, k: usize) -> anyhow::Result<TrainReport> {
    anyhow::ensure!(k > 0, "synthetic workload needs k > 0");
    let theta0 = synthetic_theta0(k);
    let curv = synthetic_curvature(k);
    let seed = cfg.seed;
    let make_step = {
        let curv = curv.clone();
        move |w: usize| -> anyhow::Result<StepFn> {
            let curv = curv.clone();
            let mut rng = Rng::new(seed ^ (w as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
            Ok(Box::new(move |params: &[f32]| {
                let mut g = vec![0.0f32; params.len()];
                for ((g, &p), &c) in g.iter_mut().zip(params).zip(&curv) {
                    *g = c * p + 0.01 * rng.normal() as f32;
                }
                Ok((synthetic_loss(params, &curv) as f32, g))
            }) as StepFn)
        }
    };
    run_core(cfg, &theta0, &make_step, move |theta| {
        let loss = synthetic_loss(theta, &curv);
        Ok((loss, 100.0 * loss / (1.0 + loss)))
    })
}

/// The generic driver: spawns `cfg.n_workers` threads, each built by
/// `make_step`, and runs the master FIFO for `cfg.total_master_steps()`
/// pushes.  `eval` maps master parameters to `(test loss, test error %)`.
fn run_core<F>(
    cfg: &TrainConfig,
    theta0: &[f32],
    make_step: &F,
    mut eval: impl FnMut(&[f32]) -> anyhow::Result<(f64, f64)>,
) -> anyhow::Result<TrainReport>
where
    F: Fn(usize) -> anyhow::Result<StepFn> + Sync,
{
    let t0 = std::time::Instant::now();
    let n = cfg.n_workers;
    let mut server = make_master(
        cfg.algorithm,
        theta0,
        LrSchedule::new(cfg.schedule.clone()),
        n,
        cfg.shards,
        crate::util::parallel::default_threads(),
    );
    server.metrics_mut().set_every(cfg.metrics_every);
    let rule = WorkerRule::for_algorithm(cfg.algorithm);
    let gamma = cfg.schedule.gamma;

    let (tx_master, rx_master) = mpsc::channel::<FromWorker>();
    let mut to_workers: Vec<mpsc::Sender<ToWorker>> = Vec::with_capacity(n);

    let total = cfg.total_master_steps();
    let mut report = TrainReport {
        algorithm: cfg.algorithm.name().to_string(),
        n_workers: n,
        ..TrainReport::default()
    };
    let eval_every = if cfg.eval_every_epochs > 0.0 {
        (cfg.eval_every_epochs * cfg.schedule.steps_per_epoch as f64).round() as u64
    } else {
        0
    };

    std::thread::scope(|scope| -> anyhow::Result<()> {
        for w in 0..n {
            let (tx_w, rx_w) = mpsc::channel::<ToWorker>();
            to_workers.push(tx_w);
            let tx_master = tx_master.clone();
            scope.spawn(move || {
                let mut step = match make_step(w) {
                    Ok(s) => s,
                    Err(e) => {
                        eprintln!("worker {w}: init failed: {e}");
                        return;
                    }
                };
                let mut v_local: Vec<f32> = vec![];
                while let Ok(ToWorker::Params(params)) = rx_w.recv() {
                    match step(&params) {
                        Ok((loss, mut msg)) => {
                            rule.apply(&mut v_local, &mut msg, gamma);
                            if tx_master
                                .send(FromWorker { worker: w, msg, loss })
                                .is_err()
                            {
                                break;
                            }
                        }
                        Err(e) => {
                            eprintln!("worker {w}: step failed: {e}");
                            break;
                        }
                    }
                }
            });
        }
        drop(tx_master);

        // Kick off: every worker gets initial (pulled) parameters.
        for (w, tx) in to_workers.iter().enumerate() {
            let p = server.pull_params(w);
            tx.send(ToWorker::Params(p)).ok();
        }

        let loss_sample = (total / 200).max(1);
        for step in 0..total {
            let FromWorker { worker, msg, loss } = rx_master
                .recv()
                .map_err(|_| anyhow::anyhow!("all workers died before step {step}"))?;
            debug_assert_eq!(server.steps_done(), step, "master step not monotone");
            if step % loss_sample == 0 {
                report.loss_curve.push((step, loss as f64));
            }
            if !loss.is_finite() {
                report.diverged = true;
            }
            server.push_update(worker, &msg);
            if step + 1 < total {
                let p = server.pull_params(worker);
                to_workers[worker].send(ToWorker::Params(p)).ok();
            }
            if eval_every > 0 && (step + 1) % eval_every == 0 {
                let (l, e) = eval(&server.theta_vec())?;
                report.curve.push(EvalPoint {
                    epoch: (step + 1) as f64 / cfg.schedule.steps_per_epoch as f64,
                    test_loss: l,
                    test_error: e,
                    sim_time: t0.elapsed().as_secs_f64(),
                });
            }
        }
        for tx in &to_workers {
            tx.send(ToWorker::Stop).ok();
        }
        Ok(())
    })?;

    let (loss, err) = eval(&server.theta_vec())?;
    report.final_test_loss = loss;
    report.final_test_error = err;
    if !loss.is_finite() {
        report.diverged = true;
        report.final_test_error = 100.0;
    }
    report.mean_gap = server.metrics().mean_gap();
    report.mean_lag = server.metrics().mean_lag();
    report.steps = total;
    report.wall_secs = t0.elapsed().as_secs_f64();
    report.sim_time = report.wall_secs; // real time is the clock here
    Ok(report)
}

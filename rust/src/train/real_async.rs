//! Real-thread asynchronous training — the §5.4 setup scaled to this host.
//!
//! Every worker is an OS thread with its **own PJRT client + compiled
//! executable** (the `xla` wrapper types are not `Send`, and separate
//! clients avoid any contention on the execution path — the analogue of
//! one process per GPU in the paper's Fig 8).  The master thread owns the
//! [`ParameterServer`] and serves a plain FIFO over an mpsc channel; on
//! every push it replies with freshly pulled parameters, exactly the
//! pull→compute→push cycle of Algorithm 1.
//!
//! The worker-side optimizer transform (DANA-Slim's momentum) runs inside
//! the worker thread via [`WorkerRule`] — state never crosses the channel,
//! matching the paper's "completely eliminates the overhead at the master".

use crate::config::TrainConfig;
use crate::math;
use crate::optim::{make_algorithm, AlgorithmKind, LrSchedule};
use crate::runtime::Engine;
use crate::server::ParameterServer;
use crate::train::data_source::{evaluate, DataSource};
use crate::train::{EvalPoint, TrainReport};
use std::sync::mpsc;

/// Worker-side message transform, replicated per thread.
#[derive(Debug, Clone, Copy)]
pub enum WorkerRule {
    /// Send the raw gradient.
    Passthrough,
    /// DANA-Slim: keep momentum locally, send `gamma*v_new + g`.
    Slim,
}

impl WorkerRule {
    pub fn for_algorithm(kind: AlgorithmKind) -> WorkerRule {
        match kind {
            AlgorithmKind::DanaSlim => WorkerRule::Slim,
            _ => WorkerRule::Passthrough,
        }
    }

    fn apply(self, v: &mut Vec<f32>, grad: &mut [f32], gamma: f32) {
        match self {
            WorkerRule::Passthrough => {}
            WorkerRule::Slim => {
                if v.len() != grad.len() {
                    *v = vec![0.0; grad.len()];
                }
                let mut send = vec![0.0f32; grad.len()];
                math::slim_worker_update(&mut send, v, grad, gamma);
                grad.copy_from_slice(&send);
            }
        }
    }
}

enum ToWorker {
    Params(Vec<f32>),
    Stop,
}

struct FromWorker {
    worker: usize,
    msg: Vec<f32>,
    loss: f32,
}

/// Run real-thread asynchronous training. Returns the report plus measured
/// throughput (master steps / wall second).
pub fn run(cfg: &TrainConfig, engine: &Engine) -> anyhow::Result<TrainReport> {
    let t0 = std::time::Instant::now();
    let n = cfg.n_workers;
    let variant = cfg.variant_name().to_string();
    let theta0 = engine.init_params(&variant)?;
    let model = engine.load_model(&variant)?; // master's eval copy
    let eval_set = DataSource::for_config(cfg).eval_set();

    let mut server = ParameterServer::new(
        make_algorithm(cfg.algorithm, &theta0, n),
        LrSchedule::new(cfg.schedule.clone()),
        n,
    );
    server.metrics.set_every(cfg.metrics_every);
    let rule = WorkerRule::for_algorithm(cfg.algorithm);
    let gamma = cfg.schedule.gamma;

    let (tx_master, rx_master) = mpsc::channel::<FromWorker>();
    let mut to_workers: Vec<mpsc::Sender<ToWorker>> = Vec::with_capacity(n);

    let total = cfg.total_master_steps();
    let artifacts = cfg.artifacts_dir.clone();
    let mut report = TrainReport {
        algorithm: cfg.algorithm.name().to_string(),
        n_workers: n,
        ..TrainReport::default()
    };
    let eval_every = if cfg.eval_every_epochs > 0.0 {
        (cfg.eval_every_epochs * cfg.schedule.steps_per_epoch as f64).round() as u64
    } else {
        0
    };

    std::thread::scope(|scope| -> anyhow::Result<()> {
        for w in 0..n {
            let (tx_w, rx_w) = mpsc::channel::<ToWorker>();
            to_workers.push(tx_w);
            let tx_master = tx_master.clone();
            let mut wcfg = cfg.clone();
            wcfg.seed = cfg.seed.wrapping_add(w as u64 * 7919);
            let variant = variant.clone();
            let artifacts = artifacts.clone();
            scope.spawn(move || {
                // Each worker owns a full engine: client + executable.
                let engine = match Engine::cpu(&artifacts) {
                    Ok(e) => e,
                    Err(e) => {
                        eprintln!("worker {w}: engine init failed: {e}");
                        return;
                    }
                };
                let model = match engine.load_model(&variant) {
                    Ok(m) => m,
                    Err(e) => {
                        eprintln!("worker {w}: load failed: {e}");
                        return;
                    }
                };
                let mut ds = DataSource::for_config(&wcfg);
                let mut v_local: Vec<f32> = vec![];
                while let Ok(ToWorker::Params(params)) = rx_w.recv() {
                    let batch = ds.next_train();
                    match model.train_step(&params, batch.input(), &batch.y) {
                        Ok((loss, mut grads)) => {
                            rule.apply(&mut v_local, &mut grads, gamma);
                            if tx_master
                                .send(FromWorker { worker: w, msg: grads, loss })
                                .is_err()
                            {
                                break;
                            }
                        }
                        Err(e) => {
                            eprintln!("worker {w}: step failed: {e}");
                            break;
                        }
                    }
                }
            });
        }
        drop(tx_master);

        // Kick off: every worker gets initial (pulled) parameters.
        for w in 0..n {
            let p = server.pull(w).to_vec();
            to_workers[w].send(ToWorker::Params(p)).ok();
        }

        let loss_sample = (total / 200).max(1);
        for step in 0..total {
            let FromWorker { worker, msg, loss } = rx_master
                .recv()
                .map_err(|_| anyhow::anyhow!("all workers died before step {step}"))?;
            if step % loss_sample == 0 {
                report.loss_curve.push((step, loss as f64));
            }
            if !loss.is_finite() {
                report.diverged = true;
            }
            server.push(worker, &msg);
            if step + 1 < total {
                let p = server.pull(worker).to_vec();
                to_workers[worker].send(ToWorker::Params(p)).ok();
            }
            if eval_every > 0 && (step + 1) % eval_every == 0 {
                let (l, e) = evaluate(&model, server.theta(), &eval_set)?;
                report.curve.push(EvalPoint {
                    epoch: (step + 1) as f64 / cfg.schedule.steps_per_epoch as f64,
                    test_loss: l,
                    test_error: e,
                    sim_time: t0.elapsed().as_secs_f64(),
                });
            }
        }
        for tx in &to_workers {
            tx.send(ToWorker::Stop).ok();
        }
        Ok(())
    })?;

    let (loss, err) = evaluate(&model, server.theta(), &eval_set)?;
    report.final_test_loss = loss;
    report.final_test_error = err;
    if !loss.is_finite() {
        report.diverged = true;
        report.final_test_error = 100.0;
    }
    report.mean_gap = server.metrics.mean_gap();
    report.mean_lag = server.metrics.mean_lag();
    report.steps = total;
    report.wall_secs = t0.elapsed().as_secs_f64();
    report.sim_time = report.wall_secs; // real time is the clock here
    Ok(report)
}

//! Real-thread asynchronous training — the §5.4 setup scaled to this host.
//!
//! Every worker is an OS thread with its **own** gradient source; the
//! master thread owns the parameter server (monolithic or sharded per
//! `cfg.shards`, or a [`crate::net::RemoteMaster`] against
//! `cfg.master_addr`) and serves a plain FIFO over an mpsc channel; on
//! every push it replies with freshly pulled parameters, exactly the
//! pull→compute→push cycle of Algorithm 1.
//!
//! Membership is elastic: a [`TrainConfig::churn`] schedule makes the
//! driver spawn worker threads mid-run on `join` and stop them on `leave`
//! (the master retires the slot, so a straggler's in-flight push is
//! rejected as a recoverable error and dropped).  Worker failures are no
//! longer invisible — a thread whose init or step errors *or panics*
//! reports an exit message; the master retires its slot (its momentum follows
//! `cfg.leave_policy`), counts it in [`TrainReport::workers_lost`], and
//! fails fast with a clear error the moment no live thread remains to make
//! FIFO progress, instead of hanging or erroring only when every sender is
//! gone.  `slow@…` churn events are a no-op here: real threads run at
//! hardware speed (the simulated drivers honor them).
//!
//! The driver is split from the gradient computation so the concurrency
//! machinery is testable without PJRT:
//!
//! * [`run`] wires a PJRT client + compiled executable per worker thread
//!   (the `xla` wrapper types are not `Send`, and separate clients avoid
//!   any contention on the execution path — the analogue of one process
//!   per GPU in the paper's Fig 8);
//! * [`run_synthetic`] wires a seeded noisy quadratic objective — the
//!   deterministic concurrency stress harness used by `rust/tests/stress.rs`.
//!
//! The worker-side optimizer transform (DANA-Slim's momentum) runs inside
//! the worker thread via [`WorkerRule`] — state never crosses the channel,
//! matching the paper's "completely eliminates the overhead at the master".
//! The hot path is allocation-free on the master side: the worker's
//! incoming message buffer is reused as its outgoing parameter buffer via
//! [`crate::server::Master::pull_into`], and the Slim transform updates the gradient in
//! place.

use crate::config::TrainConfig;
use crate::math;
use crate::optim::AlgorithmKind;
use crate::runtime::Engine;
use crate::sim::ChurnAction;
use crate::train::data_source::{evaluate, DataSource};
use crate::train::{EvalPoint, TrainReport};
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::sync::mpsc;

/// Worker-side message transform, replicated per thread.
#[derive(Debug, Clone, Copy)]
pub enum WorkerRule {
    /// Send the raw gradient.
    Passthrough,
    /// DANA-Slim: keep momentum locally, send `gamma*v_new + g`.
    Slim,
}

impl WorkerRule {
    pub fn for_algorithm(kind: AlgorithmKind) -> WorkerRule {
        match kind {
            AlgorithmKind::DanaSlim => WorkerRule::Slim,
            _ => WorkerRule::Passthrough,
        }
    }

    fn apply(self, v: &mut Vec<f32>, grad: &mut [f32], gamma: f32) {
        match self {
            WorkerRule::Passthrough => {}
            WorkerRule::Slim => {
                if v.len() != grad.len() {
                    *v = vec![0.0; grad.len()];
                }
                // in place over the gradient buffer — no per-step scratch
                math::slim_worker_update_inplace(v, grad, gamma);
            }
        }
    }
}

/// Per-thread gradient source: `params -> (train loss, message)`.
/// Created *inside* the worker thread (so it may hold non-`Send` handles
/// like a PJRT client) and never crosses threads.
pub type StepFn = Box<dyn FnMut(&[f32]) -> anyhow::Result<(f32, Vec<f32>)>>;

enum ToWorker {
    Params(Vec<f32>),
    Stop,
}

/// Worker→master messages, tagged with the slot's spawn generation so a
/// late message from a stopped incarnation cannot be misattributed to a
/// joiner that reused the slot.
enum FromWorker {
    Update { worker: usize, gen: u32, msg: Vec<f32>, loss: f32 },
    Exited { worker: usize, gen: u32, reason: String },
}

/// Best-effort message out of a caught panic payload.
fn panic_reason(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panicked".to_string()
    }
}

/// Run real-thread asynchronous training against the AOT/PJRT runtime.
pub fn run(cfg: &TrainConfig, engine: &Engine) -> anyhow::Result<TrainReport> {
    let variant = cfg.variant_name().to_string();
    let theta0 = engine.init_params(&variant)?;
    let model = engine.load_model(&variant)?; // master's eval copy
    let eval_set = DataSource::for_config(cfg).eval_set();
    let artifacts = cfg.artifacts_dir.clone();
    let worker_cfg = cfg.clone();
    let make_step = move |w: usize| -> anyhow::Result<StepFn> {
        // Each worker owns a full engine: client + executable.
        let engine = Engine::cpu(&artifacts)?;
        let model = engine.load_model(&variant)?;
        let mut wcfg = worker_cfg.clone();
        wcfg.seed = worker_cfg.seed.wrapping_add(w as u64 * 7919);
        let mut ds = DataSource::for_config(&wcfg);
        Ok(Box::new(move |params: &[f32]| {
            // keep the client alive for the executable's whole lifetime
            let _ = &engine;
            let batch = ds.next_train();
            model.train_step(params, batch.input(), &batch.y)
        }) as StepFn)
    };
    run_core(cfg, &theta0, &make_step, |theta| {
        evaluate(&model, theta, &eval_set)
    })
}

/// Deterministic starting point for the synthetic objective.
pub fn synthetic_theta0(k: usize) -> Vec<f32> {
    (0..k).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect()
}

/// Per-coordinate curvatures of the synthetic quadratic (spread over a
/// 4x condition range so momentum actually matters).
pub fn synthetic_curvature(k: usize) -> Vec<f32> {
    (0..k).map(|i| 0.25 + 0.5 * ((i % 8) as f32) / 8.0).collect()
}

/// Mean quadratic loss `J(θ) = ½·mean(cᵢ·θᵢ²)` of the synthetic objective.
pub fn synthetic_loss(theta: &[f32], curv: &[f32]) -> f64 {
    let mut loss = 0.0f64;
    for (&t, &c) in theta.iter().zip(curv) {
        loss += 0.5 * c as f64 * t as f64 * t as f64;
    }
    loss / theta.len().max(1) as f64
}

/// One noisy gradient draw of the synthetic objective:
/// `out = curv ⊙ params + 0.01·N(0,1)` — the single definition every
/// synthetic driver and test harness shares.
pub fn synthetic_grad(params: &[f32], curv: &[f32], rng: &mut Rng, out: &mut [f32]) {
    for ((g, &p), &c) in out.iter_mut().zip(params).zip(curv) {
        *g = c * p + 0.01 * rng.normal() as f32;
    }
}

/// The per-worker noise stream of the synthetic objective.
pub fn synthetic_worker_rng(seed: u64, w: usize) -> Rng {
    Rng::new(seed ^ (w as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15))
}

/// `(test loss, bounded % error proxy)` of the synthetic objective.
pub fn synthetic_eval(theta: &[f32], curv: &[f32]) -> (f64, f64) {
    let loss = synthetic_loss(theta, curv);
    (loss, 100.0 * loss / (1.0 + loss))
}

/// Run real-thread asynchronous training on a seeded noisy quadratic —
/// no PJRT, no artifacts.  Exercises the full channel/threading/server
/// machinery; the reported test loss is [`synthetic_loss`] at the master
/// parameters (test error is a bounded percent proxy of the same).
pub fn run_synthetic(cfg: &TrainConfig, k: usize) -> anyhow::Result<TrainReport> {
    anyhow::ensure!(k > 0, "synthetic workload needs k > 0");
    let theta0 = synthetic_theta0(k);
    let curv = synthetic_curvature(k);
    let seed = cfg.seed;
    let make_step = {
        let curv = curv.clone();
        move |w: usize| -> anyhow::Result<StepFn> {
            let curv = curv.clone();
            let mut rng = synthetic_worker_rng(seed, w);
            Ok(Box::new(move |params: &[f32]| {
                let mut g = vec![0.0f32; params.len()];
                synthetic_grad(params, &curv, &mut rng, &mut g);
                Ok((synthetic_loss(params, &curv) as f32, g))
            }) as StepFn)
        }
    };
    run_core(cfg, &theta0, &make_step, move |theta| {
        Ok(synthetic_eval(theta, &curv))
    })
}

/// The generic driver: spawns one thread per initial worker (and more on
/// churn joins), each built by `make_step`, and runs the master FIFO for
/// `cfg.total_master_steps()` pushes.  `eval` maps master parameters to
/// `(test loss, test error %)`.
///
/// Public so external harnesses (the stress suite) can inject failing or
/// custom gradient sources without PJRT.
pub fn run_core<F>(
    cfg: &TrainConfig,
    theta0: &[f32],
    make_step: &F,
    mut eval: impl FnMut(&[f32]) -> anyhow::Result<(f64, f64)>,
) -> anyhow::Result<TrainReport>
where
    F: Fn(usize) -> anyhow::Result<StepFn> + Sync,
{
    let t0 = std::time::Instant::now();
    let n = cfg.n_workers;
    cfg.churn.validate(n)?;
    // in-process master, or a RemoteMaster against `--master tcp://...`
    let mut server = crate::net::master_for(cfg, theta0)?;
    server.metrics_mut().set_every(cfg.metrics_every);
    let rule = WorkerRule::for_algorithm(cfg.algorithm);
    let gamma = cfg.schedule.gamma;

    let (tx_master, rx_master) = mpsc::channel::<FromWorker>();

    let total = cfg.total_master_steps();
    let mut churn: VecDeque<(u64, ChurnAction)> = cfg.churn.thresholds(total).into();
    let mut churn_rng = Rng::new(cfg.seed ^ 0x454C_4153_5449_43); // random leave victims
    let mut report = TrainReport {
        algorithm: cfg.algorithm.name().to_string(),
        n_workers: n,
        ..TrainReport::default()
    };
    let eval_every = if cfg.eval_every_epochs > 0.0 {
        (cfg.eval_every_epochs * cfg.schedule.steps_per_epoch as f64).round() as u64
    } else {
        0
    };

    std::thread::scope(|scope| -> anyhow::Result<()> {
        // Spawn (or respawn) the worker thread for a slot; used at kick-off
        // and for mid-run joins.  `gen` tags every message the incarnation
        // sends.  Init/step failures AND panics are caught and reported as
        // `Exited` — a panicking gradient source must surface as a lost
        // worker, not hang the master's recv (the master keeps a sender
        // alive, so channel disconnection can never signal thread death).
        let spawn_worker = |w: usize, gen: u32| -> mpsc::Sender<ToWorker> {
            let (tx_w, rx_w) = mpsc::channel::<ToWorker>();
            let tx_master = tx_master.clone();
            scope.spawn(move || {
                let exit = |reason: String| {
                    let _ = tx_master.send(FromWorker::Exited { worker: w, gen, reason });
                };
                let init =
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| make_step(w)));
                let mut step_fn = match init {
                    Ok(Ok(s)) => s,
                    Ok(Err(e)) => return exit(format!("init failed: {e}")),
                    Err(p) => return exit(format!("init panicked: {}", panic_reason(p))),
                };
                let mut v_local: Vec<f32> = vec![];
                loop {
                    match rx_w.recv() {
                        Ok(ToWorker::Params(params)) => {
                            let step = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                || step_fn(&params),
                            ));
                            match step {
                                Ok(Ok((loss, mut msg))) => {
                                    rule.apply(&mut v_local, &mut msg, gamma);
                                    if tx_master
                                        .send(FromWorker::Update { worker: w, gen, msg, loss })
                                        .is_err()
                                    {
                                        return;
                                    }
                                }
                                Ok(Err(e)) => return exit(format!("step failed: {e}")),
                                Err(p) => {
                                    return exit(format!("step panicked: {}", panic_reason(p)))
                                }
                            }
                        }
                        // master-initiated stop (leave or end of run)
                        Ok(ToWorker::Stop) | Err(_) => return,
                    }
                }
            });
            tx_w
        };

        // `senders[w].is_some()` IS the thread-liveness state: a slot has a
        // sender exactly while its current incarnation may still produce
        // messages the master should honor.
        let mut senders: Vec<Option<mpsc::Sender<ToWorker>>> = Vec::with_capacity(n);
        let mut thread_gen: Vec<u32> = vec![0; n];
        for w in 0..n {
            senders.push(Some(spawn_worker(w, 0)));
        }
        // Kick off: every worker gets initial (pulled) parameters.
        for (w, tx) in senders.iter().enumerate() {
            if let Some(tx) = tx {
                tx.send(ToWorker::Params(server.pull_params(w))).ok();
            }
        }

        let loss_sample = (total / 200).max(1);
        let mut step: u64 = 0;
        while step < total {
            // Fire membership events due at this master step.
            while churn.front().is_some_and(|&(at, _)| step >= at) {
                let (_, action) = churn.pop_front().expect("front checked");
                match action {
                    ChurnAction::Join => {
                        let slot = server.add_worker();
                        if slot == senders.len() {
                            senders.push(None);
                            thread_gen.push(0);
                        }
                        thread_gen[slot] = thread_gen[slot].wrapping_add(1);
                        let tx = spawn_worker(slot, thread_gen[slot]);
                        tx.send(ToWorker::Params(server.pull_params(slot))).ok();
                        senders[slot] = Some(tx);
                        report.workers_joined += 1;
                    }
                    ChurnAction::Leave(who) => {
                        // A named worker may already be gone (it crashed and
                        // was retired as an implicit leave) and lost threads
                        // may leave nobody to evict — both are no-ops, not
                        // reasons to abort the surviving run.
                        let victim = match who {
                            Some(w) if server.is_live(w) => Some(w),
                            Some(w) => {
                                eprintln!("churn: skipping leave of worker {w} (already gone)");
                                None
                            }
                            None => {
                                let live: Vec<usize> = (0..server.workers())
                                    .filter(|&i| server.is_live(i))
                                    .collect();
                                if live.is_empty() {
                                    None
                                } else {
                                    Some(live[churn_rng.below(live.len() as u64) as usize])
                                }
                            }
                        };
                        if let Some(w) = victim {
                            server.remove_worker(w, cfg.leave_policy)?;
                            if let Some(tx) = senders[w].take() {
                                tx.send(ToWorker::Stop).ok();
                            }
                            report.workers_left += 1;
                        }
                    }
                    // real threads run at hardware speed; straggler onset
                    // is only meaningful under the simulated clock
                    ChurnAction::SpeedChange(..) => {}
                }
            }

            // Fail fast: the FIFO cannot make progress once no live thread
            // remains to produce updates.
            anyhow::ensure!(
                senders.iter().any(Option::is_some),
                "no live workers left at master step {step}/{total} \
                 ({} lost, {} left); aborting instead of deadlocking",
                report.workers_lost,
                report.workers_left
            );

            match rx_master.recv().expect("master keeps a sender; recv cannot fail") {
                FromWorker::Exited { worker, gen, reason } => {
                    if gen != thread_gen[worker] || senders[worker].is_none() {
                        continue; // stale incarnation: already stopped/left
                    }
                    // A dying worker is an implicit leave: retire its slot
                    // so its momentum doesn't linger frozen in v⁰.
                    senders[worker] = None;
                    if server.is_live(worker) {
                        server.remove_worker(worker, cfg.leave_policy)?;
                    }
                    report.workers_lost += 1;
                    eprintln!("worker {worker}: {reason}");
                }
                FromWorker::Update { worker, gen, mut msg, loss } => {
                    if gen != thread_gen[worker] {
                        continue; // late push from a stopped incarnation
                    }
                    if !server.is_live(worker) {
                        // in-flight push raced a leave: recoverable, drop it
                        continue;
                    }
                    // (a remote master may be shared with other clients,
                    // whose pushes legitimately advance it between ours)
                    debug_assert!(
                        cfg.master_addr.is_some() || server.steps_done() == step,
                        "master step not monotone"
                    );
                    if step % loss_sample == 0 {
                        report.loss_curve.push((step, loss as f64));
                    }
                    if !loss.is_finite() {
                        report.diverged = true;
                    }
                    server.push_update(worker, &msg)?;
                    step += 1;
                    if step < total {
                        if let Some(tx) = &senders[worker] {
                            // round-trip buffer reuse: the worker's message
                            // buffer becomes its next parameter buffer
                            server.pull_into(worker, &mut msg);
                            tx.send(ToWorker::Params(msg)).ok();
                        }
                    }
                    if eval_every > 0 && step % eval_every == 0 {
                        let (l, e) = eval(&server.theta_vec())?;
                        report.curve.push(EvalPoint {
                            epoch: step as f64 / cfg.schedule.steps_per_epoch as f64,
                            test_loss: l,
                            test_error: e,
                            sim_time: t0.elapsed().as_secs_f64(),
                        });
                    }
                }
            }
        }
        for tx in senders.iter().flatten() {
            tx.send(ToWorker::Stop).ok();
        }
        Ok(())
    })?;

    let (loss, err) = eval(&server.theta_vec())?;
    report.final_test_loss = loss;
    report.final_test_error = err;
    if !loss.is_finite() {
        report.diverged = true;
        report.final_test_error = 100.0;
    }
    report.mean_gap = server.metrics().mean_gap();
    report.mean_lag = server.metrics().mean_lag();
    report.steps = total;
    report.wall_secs = t0.elapsed().as_secs_f64();
    report.sim_time = report.wall_secs; // real time is the clock here
    Ok(report)
}

//! Single-worker sequential baseline (the paper's dashed line): NAG with
//! the architecture's original hyperparameters — no staleness, ideal
//! accuracy and convergence.

use crate::config::TrainConfig;
use crate::optim::sgd::Nag;
use crate::optim::LrSchedule;
use crate::runtime::Engine;
use crate::sim::ExecTimeModel;
use crate::train::data_source::{evaluate, DataSource};
use crate::train::{EvalPoint, TrainReport};
use crate::util::rng::Rng;

/// Run the sequential NAG baseline for `cfg.epochs` (n_workers is ignored;
/// the schedule uses N=1 semantics: no warmup division).
pub fn run(cfg: &TrainConfig, engine: &Engine) -> anyhow::Result<TrainReport> {
    let t0 = std::time::Instant::now();
    let model = engine.load_model(&cfg.variant_name())?;
    let theta0 = engine.init_params(&cfg.variant_name())?;
    let mut ds = DataSource::for_config(cfg);
    let eval_set = ds.eval_set();

    let mut sched_cfg = cfg.schedule.clone();
    sched_cfg.n_workers = 1;
    let schedule = LrSchedule::new(sched_cfg);

    let mut cluster_rng = Rng::new(cfg.seed);
    let exec_model = ExecTimeModel::new(cfg.env, 1, cfg.batch(), &mut cluster_rng);
    let mut sim_time = 0.0;
    let mut sample_rng = cluster_rng.fork(1);

    let mut nag = Nag::new(&theta0);
    let mut hat = vec![0.0f32; theta0.len()];
    let total = cfg.total_master_steps();
    let eval_every = crate::train::driver::eval_cadence(cfg);
    let loss_sample = crate::train::driver::loss_sample_every(total);

    let mut report = TrainReport {
        algorithm: "baseline".to_string(),
        n_workers: 1,
        ..TrainReport::default()
    };

    for step in 0..total {
        let s = schedule.step_at(step);
        let batch = ds.next_train();
        nag.lookahead_params(&mut hat, s.eta, s.gamma);
        let (loss, grads) = model.train_step(&hat, batch.input(), &batch.y)?;
        nag.apply(&grads, s.eta, s.gamma);
        sim_time += exec_model.sample(0, &mut sample_rng);
        if step % loss_sample == 0 {
            report.loss_curve.push((step, loss as f64));
        }
        if eval_every > 0 && (step + 1) % eval_every == 0 {
            let (l, e) = evaluate(&model, &nag.theta, &eval_set)?;
            report.curve.push(EvalPoint {
                epoch: (step + 1) as f64 / cfg.schedule.steps_per_epoch as f64,
                test_loss: l,
                test_error: e,
                sim_time,
            });
        }
    }

    let (loss, err) = evaluate(&model, &nag.theta, &eval_set)?;
    crate::train::driver::finish_eval(&mut report, loss, err);
    report.sim_time = sim_time;
    report.steps = total;
    report.wall_secs = t0.elapsed().as_secs_f64();
    Ok(report)
}

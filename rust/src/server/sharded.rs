//! Sharded, lock-striped parameter server — the master's O(k) hot path
//! split across S contiguous shards, applied in parallel, and (since
//! ISSUE 4) **concurrently callable**: shards are the unit of locking all
//! the way down to [`Algorithm`] applies, so many serving threads can
//! drive one server at once without a global lock.
//!
//! The paper's scaling argument (§4.1, Appendix C.1) is that the master
//! must stay O(k) per update or it becomes the bottleneck before the
//! workers do.  PR 1 bought memory parallelism (shards fanned over scoped
//! threads, still one `&mut self` caller); serving over TCP then put one
//! process-wide mutex in front of it, which serialized everything again.
//! This version removes that mutex: all state is striped or sequenced —
//!
//! * **per-shard state** (θ, vᶦ, v⁰ slices, the shard's [`Algorithm`]):
//!   one `RwLock` per shard.  Pulls take *read* locks ([`Algorithm::
//!   master_send`] is a pure read), applies take the write lock of one
//!   shard at a time — a pull never queues behind a push except on the
//!   single shard currently being written, and two pushes write different
//!   shards concurrently;
//! * **sequencer** (`master_step`, schedule point, momentum-correction
//!   trigger, liveness, sliced-pull group masks): one small mutex held
//!   for O(1) work.  Every push takes a **ticket** (its master step) here;
//!   per-shard *gates* (`Mutex<u64>` + condvar) then admit applies to each
//!   shard in strict ticket order.  Any interleaving of serving threads
//!   therefore produces exactly the FIFO trajectory of the ticket order —
//!   bit-for-bit the monolithic/global-lock behaviour for that order;
//! * **per-worker pull windows** (gap/lag accounting + DC-ASGD's θ_sent):
//!   full-length retained copies of up to `pipeline + 1` outstanding
//!   pulls, one mutex per worker slot.  A worker's own requests are
//!   serial, so this lock is effectively uncontended;
//! * **membership epoch lock**: an outer `RwLock<()>`.  Pulls/pushes hold
//!   it for read; join/leave/restore/snapshot take it for write, so a
//!   membership change fans across *all* shards atomically while the data
//!   path pays one uncontended read-lock acquisition.
//!
//! **Equivalence contract.**  Unchanged from PR 1 and now concurrency-
//! hardened: a shard restricted to `[a, b)` performs bit-for-bit the
//! monolithic operations on those coordinates; whole-vector reductions
//! (gap metrics, YellowFin's tuner via the two-phase
//! [`Algorithm::apply_stats`] → merge → [`Algorithm::master_apply_with`]
//! protocol) are reduced across shards in shard order.  YellowFin's
//! global phase holds every shard's gate through both phases, so the
//! stats any apply sees are exactly the monolithic ones.  Torn reads are
//! possible only where asynchrony already permits them: a pull racing a
//! push may observe some shards pre- and some post-apply — the same
//! staleness the paper's gap measures — and never a torn single shard.
//! `rust/tests/properties.rs` pins sharded≡monolithic for all ten
//! `AlgorithmKind`s × S ∈ {1, 2, 7, 16}; `rust/tests/striped.rs` pins
//! striped-serving ≡ global-lock-serving bit-for-bit and hammers the
//! ticket protocol from many threads.

use super::metrics::{MetricRow, MetricsRecorder};
use super::{Master, MasterSnapshot, SlotStatus, MAX_PULL_WINDOW};
use crate::math;
use crate::optim::{
    claim_slot, make_algorithm, Algorithm, AlgorithmKind, ApplyStats, LeavePolicy, LrSchedule,
    StateDict, StateVec, Step, WorkerState, ANY_SLOT,
};
use crate::util::{parallel, sync};
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, RwLock};

/// Split `0..k` into `n_shards` contiguous near-equal ranges (lengths
/// differ by at most one; shard count is clamped to `max(k, 1)` so no
/// shard is empty for non-trivial k).
pub fn shard_bounds(k: usize, n_shards: usize) -> Vec<Range<usize>> {
    let s = n_shards.max(1).min(k.max(1));
    let base = k / s;
    let rem = k % s;
    let mut out = Vec::with_capacity(s);
    let mut start = 0;
    for i in 0..s {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, k);
    out
}

/// One shard: an algorithm instance over a contiguous coordinate range,
/// its own reader-writer lock, and the ticket gate that admits applies in
/// master-step order.
struct ShardCell {
    range: Range<usize>,
    alg: RwLock<Box<dyn Algorithm>>,
    /// The next master step this shard will admit for apply.
    gate: Mutex<u64>,
    gate_cv: Condvar,
    /// Lock-free mirror of `gate` for the metrics scrape path, bumped
    /// together with the mutexed value (monotone via `fetch_max`, so
    /// racing bump/repair drops can land in either order).
    gate_pos: AtomicU64,
}

impl ShardCell {
    /// Block until this shard has applied every push before `ticket`.
    fn wait_ticket(&self, ticket: u64) {
        let mut g = sync::lock(&self.gate);
        while *g < ticket {
            g = sync::wait(&self.gate_cv, g);
        }
    }
}

/// RAII gate bump: releases the shard to the next ticket even if the
/// apply panics, so one poisoned apply can wedge neither the gate chain
/// nor the whole server (the shard's lock recovery is handled by
/// [`crate::util::sync`]).
struct TicketBump<'a> {
    cell: &'a ShardCell,
    next: u64,
}

impl Drop for TicketBump<'_> {
    fn drop(&mut self) {
        *sync::lock(&self.cell.gate) = self.next;
        self.cell.gate_pos.fetch_max(self.next, Ordering::Relaxed);
        self.cell.gate_cv.notify_all();
    }
}

/// Whole-push unwind repair: if a push panics after taking its ticket,
/// shards it never reached would hold the gate chain at the dead ticket
/// forever.  This guard runs after the per-shard bumps (a no-op on the
/// normal path, where every gate already advanced) and releases any
/// shard still below `next`.  It is declared outside the scoped-thread
/// fan-out, and `std::thread::scope` joins all workers before unwinding,
/// so no apply for this ticket can still be running when it fires.
struct GateRepair<'a> {
    shards: &'a [ShardCell],
    next: u64,
}

impl Drop for GateRepair<'_> {
    fn drop(&mut self) {
        for sh in self.shards {
            let mut g = sync::lock(&sh.gate);
            if *g < self.next {
                *g = self.next;
                sh.gate_pos.fetch_max(self.next, Ordering::Relaxed);
                sh.gate_cv.notify_all();
            }
        }
    }
}

/// O(1) sequencing state, under one short mutex.
struct Seq {
    schedule: LrSchedule,
    master_step: u64,
    last_eta: f32,
    /// Slot liveness (elastic membership), authoritative copy.
    live: Vec<bool>,
    /// Per-worker mask of shards fetched since the last completed
    /// shard-sliced pull group (wire `PullShard` frames); a group counts
    /// as a full pull once every shard has been fetched.
    shard_pulled: Vec<Vec<bool>>,
    /// Pipeline depth hint: per-slot pull windows hold up to
    /// `pipeline + 1` outstanding pulls (see [`SlotPulls`]).
    pipeline: usize,
}

/// Per-slot pull window, under the slot's own mutex (a worker's requests
/// are serial on its connection, so this lock is effectively uncontended).
/// Same discipline as the monolithic server: below the cap a pull appends,
/// at the cap it refreshes the newest entry (the classic depth-0 overwrite
/// semantics); a push is judged against the front and pops it unless it is
/// the only entry.
///
/// INVARIANT LOCKSTEP with `server/mod.rs::ParameterServer::pulls`: any
/// change to the window discipline must be mirrored there — the
/// `pipelined_window_matches_monolithic_exactly` test below pins the two
/// implementations against each other.
struct SlotPulls {
    /// Outstanding pulls, oldest first: (master step at pull, parameters).
    queue: VecDeque<(u64, Vec<f32>)>,
    /// Recycled buffer for the next append.
    spare: Option<Vec<f32>>,
    /// Partially assembled shard-sliced pull group (wire `PullShard`).
    building: Option<Vec<f32>>,
    /// Mirror of `Seq::live` for this slot, kept in lockstep under this
    /// slot's mutex so the status scrape can read liveness without the
    /// sequencer lock.
    live: bool,
    /// Master step count right after this slot's last applied push
    /// (0 = never pushed since the slot was (re)claimed).
    last_push: u64,
}

impl SlotPulls {
    fn fresh(k: usize) -> SlotPulls {
        SlotPulls {
            queue: VecDeque::new(),
            spare: Some(vec![0.0; k]),
            building: None,
            live: true,
            last_push: 0,
        }
    }
}

/// Sharded drop-in for [`super::ParameterServer`]: same FIFO discipline,
/// same schedule/momentum-correction/metrics semantics, state split into
/// [`shard_bounds`] ranges — and every data-path method also available as
/// a `*_concurrent` `&self` variant safe to call from many threads (the
/// [`Master`] impl and the inherent `&mut self` methods delegate to
/// those, so single-threaded callers pay only uncontended lock traffic).
pub struct ShardedParameterServer {
    kind: AlgorithmKind,
    /// Total parameter count k.
    k: usize,
    /// Fan-out width for a single push/pull (1 = serial; concurrent
    /// callers usually provide the parallelism themselves).
    threads: usize,
    /// Persistent parked workers for the fan-out (spawned once here, not
    /// per apply); chunk boundaries match the scoped reference, so results
    /// are identical.  The submitter participates in its own job, which
    /// keeps ticket-gated push fan-outs deadlock-free (see
    /// [`parallel::WorkerPool`]).
    pool: parallel::WorkerPool,
    momentum_correction: bool,
    /// Cached `needs_apply_stats` of the algorithm (true only for rules
    /// with whole-vector reductions — YellowFin).
    needs_stats: bool,
    /// Membership epoch lock: read = data path, write = join/leave/
    /// restore/snapshot (fans across all shards atomically).
    epoch: RwLock<()>,
    seq: Mutex<Seq>,
    shards: Vec<ShardCell>,
    /// Per-slot pull windows, full length; the outer RwLock only guards
    /// slot-vector growth at joins.  Lock order: slot mutex before `seq`
    /// (both pull and push follow it; nothing acquires them reversed).
    pulls: RwLock<Vec<Mutex<SlotPulls>>>,
    /// Lock-free mirrors for the status scrape path (`GET /metrics` must
    /// take no lock `push_concurrent` wants): tickets issued so far, and
    /// live/total slot counts.
    issued: AtomicU64,
    live_ct: AtomicUsize,
    slots_ct: AtomicUsize,
    pub metrics: MetricsRecorder,
}

impl ShardedParameterServer {
    pub fn new(
        kind: AlgorithmKind,
        theta0: &[f32],
        schedule: LrSchedule,
        n_workers: usize,
        n_shards: usize,
    ) -> Self {
        let bounds = shard_bounds(theta0.len(), n_shards);
        let n_shards = bounds.len();
        let algs: Vec<Box<dyn Algorithm>> = bounds
            .iter()
            .map(|r| make_algorithm(kind, &theta0[r.clone()], n_workers))
            .collect();
        let needs_stats = algs[0].needs_apply_stats();
        let shards: Vec<ShardCell> = bounds
            .into_iter()
            .zip(algs)
            .map(|(range, alg)| ShardCell {
                range,
                alg: RwLock::new(alg),
                gate: Mutex::new(0),
                gate_cv: Condvar::new(),
                gate_pos: AtomicU64::new(0),
            })
            .collect();
        let last_eta = schedule.eta_at(0);
        let threads = crate::util::parallel::default_threads();
        ShardedParameterServer {
            kind,
            k: theta0.len(),
            threads,
            pool: parallel::WorkerPool::new(threads),
            momentum_correction: true,
            needs_stats,
            epoch: RwLock::new(()),
            seq: Mutex::new(Seq {
                schedule,
                master_step: 0,
                last_eta,
                live: vec![true; n_workers],
                shard_pulled: vec![vec![false; n_shards]; n_workers],
                pipeline: 0,
            }),
            shards,
            pulls: RwLock::new(
                (0..n_workers)
                    .map(|_| Mutex::new(SlotPulls::fresh(theta0.len())))
                    .collect(),
            ),
            issued: AtomicU64::new(0),
            live_ct: AtomicUsize::new(n_workers),
            slots_ct: AtomicUsize::new(n_workers),
            metrics: MetricsRecorder::default(),
        }
    }

    /// Configure the pipeline window (depth = `--pipeline-depth`): sizes
    /// the per-slot pull windows to `depth + 1` and forwards the staleness
    /// hint to every shard's algorithm.  Setup-time (before the server is
    /// shared), but `&self` so both trait paths can reach it.
    pub fn set_pipeline(&self, depth: usize) {
        let depth = depth.min(MAX_PULL_WINDOW - 1);
        sync::lock(&self.seq).pipeline = depth;
        for sh in &self.shards {
            sync::write(&sh.alg).set_staleness_hint(depth);
        }
    }

    /// Cap the worker-pool fan-out of ONE push/pull (1 = serial shard
    /// loop, and the pool spawns no threads at all).  Concurrent serving
    /// threads each fan out independently, so serving configurations
    /// usually want 1 here.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self.pool = parallel::WorkerPool::new(self.threads);
        self
    }

    pub fn with_momentum_correction(mut self, on: bool) -> Self {
        self.momentum_correction = on;
        self
    }

    pub fn kind(&self) -> AlgorithmKind {
        self.kind
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The contiguous coordinate ranges of the shards, in order.
    pub fn shard_ranges(&self) -> Vec<Range<usize>> {
        self.shards.iter().map(|sh| sh.range.clone()).collect()
    }

    /// Worker slots ever allocated (live + retired).
    pub fn n_workers(&self) -> usize {
        sync::lock(&self.seq).live.len()
    }

    /// Workers currently in the cluster.
    pub fn n_live(&self) -> usize {
        sync::lock(&self.seq).live.iter().filter(|&&l| l).count()
    }

    pub fn worker_is_live(&self, worker: usize) -> bool {
        sync::lock(&self.seq).live.get(worker).copied().unwrap_or(false)
    }

    pub fn master_step(&self) -> u64 {
        sync::lock(&self.seq).master_step
    }

    pub fn param_count(&self) -> usize {
        self.k
    }

    /// Hyperparameters for the *current* master step.
    pub fn current_step(&self) -> Step {
        let q = sync::lock(&self.seq);
        q.schedule.step_at(q.master_step)
    }

    /// One consistent (step, schedule point, live, slots) read — the wire
    /// server builds its reply headers from this with a single lock trip.
    pub fn status_concurrent(&self) -> (u64, Step, usize, usize) {
        let q = sync::lock(&self.seq);
        (
            q.master_step,
            q.schedule.step_at(q.master_step),
            q.live.iter().filter(|&&l| l).count(),
            q.live.len(),
        )
    }

    /// Live/total worker counts from the atomic mirrors — scrape path,
    /// takes no locks at all.
    pub fn worker_counts_relaxed(&self) -> (usize, usize) {
        (
            self.live_ct.load(Ordering::Relaxed),
            self.slots_ct.load(Ordering::Relaxed),
        )
    }

    /// Per-shard `(gate position, ticket backlog)` from the atomic
    /// mirrors — scrape path, takes no locks at all.  The backlog is the
    /// number of issued tickets the shard has not admitted yet; racing
    /// pushes can make it transiently off by the race width, which is
    /// exactly the queueing signal a monitor wants.
    pub fn shard_gate_stats(&self) -> Vec<(u64, u64)> {
        let issued = self.issued.load(Ordering::Relaxed);
        self.shards
            .iter()
            .map(|sh| {
                let pos = sh.gate_pos.load(Ordering::Relaxed);
                (pos, issued.saturating_sub(pos))
            })
            .collect()
    }

    /// Per-slot status table for `GET /status`: liveness, window depth
    /// and last-push step read under each slot's own mutex (effectively
    /// uncontended — a worker's requests are serial on its connection),
    /// never the sequencer lock.
    pub fn slot_table_concurrent(&self) -> Vec<SlotStatus> {
        let slots = sync::read(&self.pulls);
        slots
            .iter()
            .map(|m| {
                let sp = sync::lock(m);
                SlotStatus {
                    live: sp.live,
                    window: sp.queue.len(),
                    last_push: sp.last_push,
                }
            })
            .collect()
    }

    /// Store the membership mirrors from the authoritative `Seq::live`
    /// (callers hold the seq lock, so the stores publish a consistent
    /// count).
    fn refresh_membership_mirrors(&self, q: &Seq) {
        self.live_ct
            .store(q.live.iter().filter(|&&l| l).count(), Ordering::Relaxed);
        self.slots_ct.store(q.live.len(), Ordering::Relaxed);
    }

    /// Assemble the master parameters from all shards.  Concurrent-safe;
    /// racing pushes may be visible on some shards and not others (the
    /// usual asynchronous staleness), never within a shard.
    pub fn theta_vec(&self) -> Vec<f32> {
        let _e = sync::read(&self.epoch);
        let mut out = vec![0.0f32; self.k];
        for sh in &self.shards {
            out[sh.range.clone()].copy_from_slice(sync::read(&sh.alg).theta());
        }
        out
    }

    // ------------------------------------------------ concurrent data path

    /// Worker `worker` pulls parameters (owned).  See [`Self::pull_into_concurrent`].
    pub fn pull_concurrent(&self, worker: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.k];
        self.pull_into_concurrent(worker, &mut out)?;
        Ok(out)
    }

    /// Allocation-free concurrent pull: each shard runs its algorithm's
    /// (read-only) `master_send` under the shard's *read* lock, so pulls
    /// proceed in parallel with each other and with applies on other
    /// shards.  The retained copy lands in the slot's pull window under
    /// the worker's own slot mutex (window discipline: see [`SlotPulls`]).
    pub fn pull_into_concurrent(&self, worker: usize, out: &mut [f32]) -> anyhow::Result<()> {
        anyhow::ensure!(
            out.len() == self.k,
            "pull buffer length {} != parameter count {}",
            out.len(),
            self.k
        );
        let _e = sync::read(&self.epoch);
        let slots = sync::read(&self.pulls);
        anyhow::ensure!(
            worker < slots.len(),
            "pull for retired/unknown worker {worker}"
        );
        let mut sp = sync::lock(&slots[worker]);
        let (t, s, cap) = {
            let mut q = sync::lock(&self.seq);
            anyhow::ensure!(
                q.live.get(worker).copied().unwrap_or(false),
                "pull for retired/unknown worker {worker}"
            );
            let t = q.master_step;
            // a full pull supersedes any half-finished sliced pull group
            q.shard_pulled[worker].fill(false);
            (t, q.schedule.step_at(t), q.pipeline + 1)
        };
        // destination for the retained copy: refresh the newest window
        // entry at the cap, else append (recycling the spare buffer)
        let mut keep = if sp.queue.len() >= cap {
            let (_, buf) = sp.queue.pop_back().expect("cap >= 1");
            buf
        } else {
            let mut buf = sp.spare.take().unwrap_or_default();
            buf.resize(self.k, 0.0);
            buf
        };
        // Pre-split both buffers so each scoped thread owns disjoint
        // destinations.
        let mut work: Vec<(&ShardCell, &mut [f32], &mut [f32])> =
            Vec::with_capacity(self.shards.len());
        let mut out_rest: &mut [f32] = out;
        let mut keep_rest: &mut [f32] = &mut keep;
        for sh in &self.shards {
            let (o, o_rem) = std::mem::take(&mut out_rest).split_at_mut(sh.range.len());
            let (c, c_rem) = std::mem::take(&mut keep_rest).split_at_mut(sh.range.len());
            work.push((sh, o, c));
            out_rest = o_rem;
            keep_rest = c_rem;
        }
        self.pool.par_chunks_mut(&mut work, |_, group| {
            for (sh, o, c) in group.iter_mut() {
                let alg = sync::read(&sh.alg);
                alg.master_send(worker, o, s);
                c.copy_from_slice(o);
            }
        });
        sp.queue.push_back((t, keep));
        Ok(())
    }

    /// One shard slice of a pull (wire `PullShard`): same read-lock path
    /// restricted to shard `shard`.  A worker's sliced pulls assemble in
    /// the slot's `building` buffer and count as one full pull (one window
    /// entry, for the push-before-pull guard and lag accounting) once
    /// every shard has been fetched.
    pub fn pull_shard_concurrent(&self, worker: usize, shard: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::new();
        self.pull_shard_into_concurrent(worker, shard, &mut out)?;
        Ok(out)
    }

    /// [`Self::pull_shard_concurrent`] into a caller-retained buffer (the
    /// serving loop's per-connection scratch) — no allocation when the
    /// buffer already has the shard's capacity.
    pub fn pull_shard_into_concurrent(
        &self,
        worker: usize,
        shard: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            shard < self.shards.len(),
            "pull for shard {shard} of {}",
            self.shards.len()
        );
        let _e = sync::read(&self.epoch);
        let slots = sync::read(&self.pulls);
        anyhow::ensure!(
            worker < slots.len(),
            "pull for retired/unknown worker {worker}"
        );
        let mut sp = sync::lock(&slots[worker]);
        let (t, s, cap, complete) = {
            let mut q = sync::lock(&self.seq);
            anyhow::ensure!(
                q.live.get(worker).copied().unwrap_or(false),
                "pull for retired/unknown worker {worker}"
            );
            let t = q.master_step;
            q.shard_pulled[worker][shard] = true;
            let complete = q.shard_pulled[worker].iter().all(|&m| m);
            if complete {
                q.shard_pulled[worker].fill(false);
            }
            (t, q.schedule.step_at(t), q.pipeline + 1, complete)
        };
        let sh = &self.shards[shard];
        out.clear();
        out.resize(sh.range.len(), 0.0);
        {
            let alg = sync::read(&sh.alg);
            alg.master_send(worker, out, s);
        }
        let mut building = sp.building.take().unwrap_or_default();
        building.resize(self.k, 0.0);
        building[sh.range.clone()].copy_from_slice(out);
        if complete {
            // the assembled group becomes one window entry, pulled at the
            // completion step (matching the monolithic accounting)
            if sp.queue.len() >= cap {
                let (_, old) = sp.queue.pop_back().expect("cap >= 1");
                sp.spare = Some(old);
            }
            sp.queue.push_back((t, building));
        } else {
            sp.building = Some(building);
        }
        Ok(())
    }

    /// Concurrent push: take a ticket under the sequencer, then apply to
    /// each shard under its write lock in strict ticket order (the gates
    /// make any thread interleaving equivalent to the ticket-order FIFO).
    /// Mirrors the monolithic push exactly: validation, schedule +
    /// momentum correction, metric tap (reduced across shards in shard
    /// order), then the (possibly two-phase) apply — judged against the
    /// *front* of the slot's pull window (the parameters the gradient was
    /// computed on under a pipelined driver), which is consumed unless it
    /// is the only entry.  Returns the applied [`Step`] and the ticket
    /// (the master step the push settled as).
    pub fn push_concurrent(&self, worker: usize, msg: &[f32]) -> anyhow::Result<(Step, u64)> {
        self.push_concurrent_with(worker, msg, None)
    }

    /// Phase 1 of the cluster's two-phase apply: the additive statistics
    /// partials this push would produce over this server's coordinates,
    /// merged across shards in shard order — read-only (shard *read*
    /// locks, no ticket), nothing applied or consumed.  Coherent with the
    /// later commit under the fan-out client's per-worker serialization
    /// (a worker's stage and commit are one logical push; no other push
    /// from that client interleaves between them).
    pub fn push_stats_concurrent(&self, worker: usize, msg: &[f32]) -> anyhow::Result<ApplyStats> {
        let _e = sync::read(&self.epoch);
        let slots = sync::read(&self.pulls);
        anyhow::ensure!(
            worker < slots.len(),
            "push from unknown worker {worker} (slots: {})",
            slots.len()
        );
        let sp = sync::lock(&slots[worker]);
        {
            let q = sync::lock(&self.seq);
            anyhow::ensure!(q.live[worker], "push from retired worker {worker}");
        }
        anyhow::ensure!(
            !sp.queue.is_empty(),
            "worker {worker} pushed before ever pulling"
        );
        anyhow::ensure!(
            msg.len() == self.k,
            "staged push length {} != parameter count {}",
            msg.len(),
            self.k
        );
        let sent: &[f32] = &sp.queue.front().expect("validated non-empty").1;
        let mut stats = ApplyStats::default();
        for sh in &self.shards {
            let r = sh.range.clone();
            let alg = sync::read(&sh.alg);
            stats.merge(&alg.apply_stats(worker, &msg[r.clone()], &sent[r]));
        }
        Ok(stats)
    }

    /// [`Self::push_concurrent`] with an optional caller-provided global
    /// statistics override (phase 2 of the cluster's two-phase apply).
    /// With `Some(stats)` the local statistics pass is skipped entirely —
    /// the provided sums stand in for it, elementwise fan-out applies.
    pub fn push_concurrent_with(
        &self,
        worker: usize,
        msg: &[f32],
        provided: Option<&ApplyStats>,
    ) -> anyhow::Result<(Step, u64)> {
        let _e = sync::read(&self.epoch);
        let slots = sync::read(&self.pulls);
        anyhow::ensure!(
            worker < slots.len(),
            "push from unknown worker {worker} (slots: {})",
            slots.len()
        );
        let mut sp = sync::lock(&slots[worker]);
        // All failure paths must precede ticket assignment: a taken ticket
        // is always applied, or the gate chain would wedge.
        let (ticket, s, rescale, want_metrics, lag) = {
            let mut q = sync::lock(&self.seq);
            anyhow::ensure!(q.live[worker], "push from retired worker {worker}");
            anyhow::ensure!(
                !sp.queue.is_empty(),
                "worker {worker} pushed before ever pulling"
            );
            anyhow::ensure!(
                msg.len() == self.k,
                "message length {} != parameter count {}",
                msg.len(),
                self.k
            );
            let t = q.master_step;
            let s = q.schedule.step_at(t);
            let rescale = if self.momentum_correction && s.eta != q.last_eta && q.last_eta > 0.0
            {
                Some(s.eta / q.last_eta)
            } else {
                None
            };
            q.last_eta = s.eta;
            let lag = t - sp.queue.front().expect("validated non-empty").0;
            q.master_step = t + 1;
            (t, s, rescale, self.metrics.wants(t), lag)
        };
        // Scrape-path taps: `fetch_max` keeps `issued` monotone when
        // concurrent pushes publish their tickets out of order.
        self.issued.fetch_max(ticket + 1, Ordering::Relaxed);
        self.metrics.note_push(lag);
        let _repair = GateRepair { shards: &self.shards, next: ticket + 1 };
        let sent: &[f32] = &sp.queue.front().expect("validated non-empty").1;
        // (gap_sq, msg_sq) partials per shard, reduced in shard order.
        let mut partials: Vec<(f64, f64)> = vec![(0.0, 0.0); self.shards.len()];

        if self.needs_stats && provided.is_none() {
            // Whole-vector reductions (YellowFin): hold every shard's gate
            // through both phases so the globally merged statistics are
            // exactly what the monolithic apply would compute.
            for sh in &self.shards {
                sh.wait_ticket(ticket);
            }
            let mut stats = ApplyStats::default();
            for (i, sh) in self.shards.iter().enumerate() {
                let r = sh.range.clone();
                let mut alg = sync::write(&sh.alg);
                if let Some(ratio) = rescale {
                    alg.rescale_momentum(ratio);
                }
                if want_metrics {
                    partials[i] = (
                        math::sub_norm_sq(alg.theta(), &sent[r.clone()]),
                        math::norm2_sq(&msg[r.clone()]),
                    );
                }
                stats.merge(&alg.apply_stats(worker, &msg[r.clone()], &sent[r]));
            }
            for sh in &self.shards {
                let _bump = TicketBump { cell: sh, next: ticket + 1 };
                let r = sh.range.clone();
                let mut alg = sync::write(&sh.alg);
                alg.master_apply_with(worker, &msg[r.clone()], &sent[r], s, &stats);
            }
        } else {
            // Elementwise rules: one ticket-ordered pass per shard, fanned
            // out over the worker pool.  Each shard's gate admits tickets
            // in order, so overlapping pushes pipeline across shards.
            // A provided override carries globally merged statistics from
            // a cluster-wide staging pass, so even stats-hungry rules take
            // this path when the caller supplies them.
            let stats = provided.copied().unwrap_or_default();
            let sent_ref: &[f32] = sent;
            let mut work: Vec<(&ShardCell, &mut (f64, f64))> =
                self.shards.iter().zip(partials.iter_mut()).collect();
            // Pool, not scope: parts below block in `wait_ticket`, and the
            // pool's submitter-participation rule is what keeps concurrent
            // gated pushes deadlock-free (see `parallel::WorkerPool`).
            self.pool.par_chunks_mut(&mut work, |_, group| {
                for (sh, partial) in group.iter_mut() {
                    sh.wait_ticket(ticket);
                    let _bump = TicketBump { cell: sh, next: ticket + 1 };
                    let r = sh.range.clone();
                    let mut alg = sync::write(&sh.alg);
                    if let Some(ratio) = rescale {
                        alg.rescale_momentum(ratio);
                    }
                    if want_metrics {
                        **partial = (
                            math::sub_norm_sq(alg.theta(), &sent_ref[r.clone()]),
                            math::norm2_sq(&msg[r.clone()]),
                        );
                    }
                    alg.master_apply_with(worker, &msg[r.clone()], &sent_ref[r], s, &stats);
                }
            });
        }

        if want_metrics {
            let (mut gap_sq, mut msg_sq) = (0.0f64, 0.0f64);
            for (g, m) in &partials {
                gap_sq += g;
                msg_sq += m;
            }
            let kf = self.k as f64;
            let gap = gap_sq.sqrt() / kf.sqrt();
            let msg_norm = msg_sq.sqrt();
            self.metrics.record(MetricRow {
                step: ticket,
                worker,
                gap,
                norm_gap: if msg_norm > 0.0 { gap * kf.sqrt() / msg_norm } else { 0.0 },
                lag,
                eta: s.eta,
                msg_norm,
            });
        }
        // consume the front entry unless it is the only one (the classic
        // re-push-against-latest-pull semantics at depth 0)
        sp.last_push = ticket + 1;
        if sp.queue.len() > 1 {
            let (_, buf) = sp.queue.pop_front().expect("len > 1");
            sp.spare = Some(buf);
        }
        Ok((s, ticket))
    }

    // ------------------------------------------------ membership (epoch)

    /// A worker joins: the membership change fans out across *all* shards
    /// under the epoch write lock (no pull/push in flight), so the
    /// sharded≡monolithic contract holds through churn — every shard
    /// allocates the same slot ([`claim_slot`] is deterministic).
    pub fn add_worker_concurrent(&self) -> usize {
        let _e = sync::write(&self.epoch);
        let mut q = sync::lock(&self.seq);
        let mut pulls = sync::write(&self.pulls);
        self.add_worker_inner(&mut q, &mut pulls)
    }

    fn add_worker_inner(&self, q: &mut Seq, pulls: &mut Vec<Mutex<SlotPulls>>) -> usize {
        let slot = claim_slot(&mut q.live);
        for sh in &self.shards {
            let alg_slot = sync::write(&sh.alg).add_worker();
            debug_assert!(
                alg_slot == ANY_SLOT || alg_slot == slot,
                "shard allocated slot {alg_slot}, server allocated {slot}"
            );
        }
        if slot == pulls.len() {
            pulls.push(Mutex::new(SlotPulls::fresh(self.k)));
            q.shard_pulled.push(vec![false; self.shards.len()]);
        } else {
            *sync::lock(&pulls[slot]) = SlotPulls::fresh(self.k);
            q.shard_pulled[slot].fill(false);
        }
        self.refresh_membership_mirrors(q);
        slot
    }

    /// A worker leaves: retire its slot on every shard atomically under
    /// the epoch write lock.
    pub fn remove_worker_concurrent(
        &self,
        worker: usize,
        policy: LeavePolicy,
    ) -> anyhow::Result<()> {
        let _e = sync::write(&self.epoch);
        let mut q = sync::lock(&self.seq);
        let pulls = sync::write(&self.pulls);
        self.remove_worker_inner(&mut q, &pulls, worker, policy)
    }

    fn remove_worker_inner(
        &self,
        q: &mut Seq,
        pulls: &[Mutex<SlotPulls>],
        worker: usize,
        policy: LeavePolicy,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            q.live.get(worker).copied().unwrap_or(false),
            "remove_worker: worker {worker} is not live (slots: {})",
            q.live.len()
        );
        q.live[worker] = false;
        q.shard_pulled[worker].fill(false);
        // the leaver's pull window dies with it: a rejoiner must pull
        {
            let mut sp = sync::lock(&pulls[worker]);
            *sp = SlotPulls::fresh(self.k);
            sp.live = false;
        }
        for sh in &self.shards {
            sync::write(&sh.alg).remove_worker(worker, policy);
        }
        self.refresh_membership_mirrors(q);
        Ok(())
    }

    /// Assemble a layout-independent snapshot under the epoch write lock
    /// (quiescent: no pull/push in flight): coordinate-aligned state is
    /// concatenated across shards in range order; shard-replicated
    /// scalars are taken from shard 0 (membership fan-out and the
    /// two-phase apply keep every shard's copy in lockstep).
    pub fn snapshot_concurrent(&self) -> anyhow::Result<MasterSnapshot> {
        let _e = sync::write(&self.epoch);
        let q = sync::lock(&self.seq);
        let slots = sync::read(&self.pulls);
        // half-assembled sliced groups are connection state, not training
        // state — only the completed pull windows are snapshotted
        let pulls: Vec<Vec<(u64, Vec<f32>)>> = slots
            .iter()
            .map(|m| sync::lock(m).queue.iter().cloned().collect())
            .collect();
        let mut theta = vec![0.0f32; self.k];
        let mut state: StateDict = Vec::new();
        for (si, sh) in self.shards.iter().enumerate() {
            let alg = sync::read(&sh.alg);
            theta[sh.range.clone()].copy_from_slice(alg.theta());
            let piece = alg.state_dict();
            if si == 0 {
                state = piece;
                continue;
            }
            anyhow::ensure!(
                piece.len() == state.len(),
                "shard {si} state entry count {} != shard 0's {}",
                piece.len(),
                state.len()
            );
            for ((name, acc), (pname, pval)) in state.iter_mut().zip(piece) {
                anyhow::ensure!(
                    *name == pname,
                    "shard {si} state entry {pname:?} != shard 0's {name:?}"
                );
                match (acc, pval) {
                    (StateVec::Coord(a), StateVec::Coord(b)) => a.extend_from_slice(&b),
                    (StateVec::PerWorker(a), StateVec::PerWorker(b)) => {
                        anyhow::ensure!(
                            a.len() == b.len(),
                            "shard {si} state {name:?}: slot count mismatch"
                        );
                        for (av, bv) in a.iter_mut().zip(b) {
                            av.extend_from_slice(&bv);
                        }
                    }
                    (StateVec::Scalars(_), StateVec::Scalars(_)) => {}
                    _ => anyhow::bail!("shard {si} state {name:?}: shape mismatch"),
                }
            }
        }
        Ok(MasterSnapshot {
            kind: self.kind,
            master_step: q.master_step,
            last_eta: q.last_eta,
            theta,
            live: q.live.clone(),
            pulls,
            state,
        })
    }

    /// Restore a snapshot onto a freshly constructed server; see
    /// [`Master::restore`].  Also fast-forwards every shard's ticket gate
    /// to the snapshot's master step.
    pub fn restore_concurrent(&self, snap: &MasterSnapshot) -> anyhow::Result<()> {
        snap.validate(self.kind, self.k)?;
        let _e = sync::write(&self.epoch);
        let mut q = sync::lock(&self.seq);
        anyhow::ensure!(
            q.master_step == 0 && q.live.iter().all(|&l| l),
            "restore target must be freshly constructed"
        );
        anyhow::ensure!(
            q.live.len() <= snap.slots(),
            "restore target has {} slots, snapshot only {}",
            q.live.len(),
            snap.slots()
        );
        {
            // Replay membership so the algorithms' internal liveness (and
            // any live-count-derived scalars like LWP's τ) matches the
            // snapshot, then overwrite all state.
            let mut pulls = sync::write(&self.pulls);
            while q.live.len() < snap.slots() {
                self.add_worker_inner(&mut q, &mut pulls);
            }
            for (w, &alive) in snap.live.iter().enumerate() {
                if !alive {
                    self.remove_worker_inner(&mut q, &pulls, w, LeavePolicy::Retire)?;
                }
            }
            for (slot, window) in pulls.iter().zip(&snap.pulls) {
                let mut sp = sync::lock(slot);
                sp.queue = window.iter().cloned().collect();
                sp.building = None;
            }
        }
        for sh in &self.shards {
            let r = sh.range.clone();
            let mut alg = sync::write(&sh.alg);
            alg.set_theta(&snap.theta[r.clone()]);
            // Slice the full-length dict down to this shard's range;
            // scalars broadcast verbatim.
            let local: StateDict = snap
                .state
                .iter()
                .map(|(name, val)| {
                    let v = match val {
                        StateVec::Coord(v) => StateVec::Coord(v[r.clone()].to_vec()),
                        StateVec::PerWorker(vs) => StateVec::PerWorker(
                            vs.iter().map(|v| v[r.clone()].to_vec()).collect(),
                        ),
                        StateVec::Scalars(s) => StateVec::Scalars(s.clone()),
                    };
                    (name.clone(), v)
                })
                .collect();
            alg.load_state_dict(&local)?;
            *sync::lock(&sh.gate) = snap.master_step;
            sh.gate_pos.store(snap.master_step, Ordering::Relaxed);
        }
        q.master_step = snap.master_step;
        q.last_eta = snap.last_eta;
        self.issued.store(snap.master_step, Ordering::Relaxed);
        Ok(())
    }

    // ------------------------------------------------ single-caller API

    /// Worker `worker` pulls parameters (single-caller convenience).
    pub fn pull(&mut self, worker: usize) -> Vec<f32> {
        self.pull_concurrent(worker).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Allocation-free pull into a caller-retained k-length buffer.
    pub fn pull_into_buf(&mut self, worker: usize, out: &mut [f32]) {
        if let Err(e) = self.pull_into_concurrent(worker, out) {
            panic!("{e}");
        }
    }

    /// Worker `worker` delivers its message; see [`Self::push_concurrent`].
    /// Returns the applied [`Step`] and the settled master step.
    pub fn push(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<(Step, u64)> {
        self.push_concurrent(worker, msg)
    }

    /// Outstanding pulls in `worker`'s window (tests/diagnostics).
    pub fn outstanding_pulls(&self, worker: usize) -> usize {
        let slots = sync::read(&self.pulls);
        slots
            .get(worker)
            .map(|m| sync::lock(m).queue.len())
            .unwrap_or(0)
    }

    pub fn add_worker(&mut self) -> usize {
        self.add_worker_concurrent()
    }

    pub fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        self.remove_worker_concurrent(worker, policy)
    }
}

impl Master for ShardedParameterServer {
    fn algo_kind(&self) -> AlgorithmKind {
        self.kind
    }

    fn workers(&self) -> usize {
        self.n_workers()
    }

    fn live_workers(&self) -> usize {
        self.n_live()
    }

    fn is_live(&self, worker: usize) -> bool {
        self.worker_is_live(worker)
    }

    fn add_worker(&mut self) -> usize {
        self.add_worker_concurrent()
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        self.remove_worker_concurrent(worker, policy)
    }

    fn steps_done(&self) -> u64 {
        self.master_step()
    }

    fn slot_stats(&self, worker: usize) -> (usize, u64) {
        let slots = sync::read(&self.pulls);
        slots
            .get(worker)
            .map(|m| {
                let sp = sync::lock(m);
                (sp.queue.len(), sp.last_push)
            })
            .unwrap_or((0, 0))
    }

    fn param_len(&self) -> usize {
        self.k
    }

    fn step_now(&self) -> Step {
        self.current_step()
    }

    fn theta_vec(&self) -> Vec<f32> {
        ShardedParameterServer::theta_vec(self)
    }

    fn pull_params(&mut self, worker: usize) -> Vec<f32> {
        self.pull(worker)
    }

    fn pull_into(&mut self, worker: usize, out: &mut [f32]) {
        self.pull_into_buf(worker, out);
    }

    fn push_update(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        self.push_concurrent(worker, msg).map(|(s, _)| s)
    }

    fn push_stats(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<ApplyStats> {
        self.push_stats_concurrent(worker, msg)
    }

    fn push_update_with(
        &mut self,
        worker: usize,
        msg: &[f32],
        stats: &ApplyStats,
    ) -> anyhow::Result<Step> {
        self.push_concurrent_with(worker, msg, Some(stats))
            .map(|(s, _)| s)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        self.set_pipeline(depth);
    }

    fn make_worker_state(&self) -> WorkerState {
        // Worker state is full-length, not shard-length: size the momentum
        // buffer to k when the algorithm keeps one (DANA-Slim).  The
        // worker-side transform re-sizes on first use anyway, so this only
        // preserves the monolithic server's eager allocation.
        let mut ws = sync::read(&self.shards[0].alg).make_worker_state();
        if !ws.v.is_empty() {
            ws.v = vec![0.0; self.k];
        }
        ws
    }

    fn worker_transform(&self, ws: &mut WorkerState, grad: &mut [f32], s: Step) {
        // The worker half is shard-agnostic (it only touches worker-local
        // state and the full gradient), so any shard's instance serves.
        sync::read(&self.shards[0].alg).worker_message(ws, grad, s);
    }

    fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut MetricsRecorder {
        &mut self.metrics
    }

    fn snapshot(&self) -> anyhow::Result<MasterSnapshot> {
        self.snapshot_concurrent()
    }

    fn restore(&mut self, snap: &MasterSnapshot) -> anyhow::Result<()> {
        self.restore_concurrent(snap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ScheduleConfig;

    fn schedule(n: usize) -> LrSchedule {
        LrSchedule::new(ScheduleConfig {
            warmup_epochs: 0.0,
            decay_epochs: vec![],
            steps_per_epoch: 10,
            n_workers: n,
            ..ScheduleConfig::default()
        })
    }

    // shard_bounds partition invariants are pinned by the randomized
    // property `prop_shard_bounds_partition` in rust/tests/properties.rs.

    #[test]
    fn pull_push_cycle_advances_master() {
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::Asgd,
            &[1.0f32; 10],
            schedule(2),
            2,
            3,
        );
        let p = ps.pull(0);
        assert_eq!(p, vec![1.0; 10]);
        ps.push(0, &[1.0; 10]).unwrap();
        assert_eq!(ps.master_step(), 1);
        assert!(ps.theta_vec()[0] < 1.0);
        assert_eq!(ps.n_shards(), 3);
    }

    #[test]
    fn push_without_pull_is_recoverable_error() {
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::Asgd,
            &[1.0f32; 4],
            schedule(2),
            2,
            2,
        );
        let err = ps.push(1, &[0.0; 4]).unwrap_err();
        assert!(err.to_string().contains("pushed before ever pulling"));
        assert_eq!(ps.master_step(), 0, "failed push must not take a ticket");
        ps.pull(1);
        ps.push(1, &[0.0; 4]).unwrap();
    }

    #[test]
    fn membership_fans_out_across_all_shards() {
        let k = 9;
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::DanaZero,
            &vec![0.0f32; k],
            schedule(2),
            2,
            4,
        );
        ps.pull(0);
        ps.push(0, &vec![1.0f32; k]).unwrap();
        // worker 0 leaves (retire): every shard's v⁰ slice drops its vᶦ,
        // so a fresh pull equals plain theta again (zero look-ahead).
        ps.remove_worker(0, LeavePolicy::Retire).unwrap();
        assert_eq!(ps.n_live(), 1);
        assert!(ps.push(0, &vec![1.0f32; k]).is_err(), "retired push rejected");
        let hat = ps.pull(1);
        assert_eq!(hat, ps.theta_vec(), "v0 retired on every shard");
        // rejoin reuses slot 0 on every shard
        assert_eq!(ps.add_worker(), 0);
        let p = ps.pull(0);
        assert_eq!(p.len(), k);
        ps.push(0, &vec![0.5f32; k]).unwrap();
    }

    #[test]
    fn shard_count_clamps_to_k() {
        let ps = ShardedParameterServer::new(
            AlgorithmKind::DanaZero,
            &[0.5f32; 3],
            schedule(1),
            1,
            16,
        );
        assert_eq!(ps.n_shards(), 3);
        assert_eq!(ps.theta_vec(), vec![0.5; 3]);
    }

    #[test]
    fn dana_lookahead_send_spans_shards() {
        // After one update the look-ahead hat differs from theta on every
        // coordinate, including across shard boundaries.
        let k = 9;
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::DanaZero,
            &vec![0.0f32; k],
            schedule(2),
            2,
            4,
        );
        ps.pull(0);
        ps.push(0, &vec![1.0f32; k]).unwrap();
        let theta = ps.theta_vec();
        let hat = ps.pull(1);
        for i in 0..k {
            assert!(
                (theta[i] - hat[i]).abs() > 0.0,
                "coordinate {i}: look-ahead did not differ"
            );
        }
    }

    #[test]
    fn serial_and_threaded_fanout_agree() {
        let k = 37;
        let theta0: Vec<f32> = (0..k).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut a = ShardedParameterServer::new(
            AlgorithmKind::DanaDc,
            &theta0,
            schedule(3),
            3,
            5,
        )
        .with_threads(1);
        let mut b = ShardedParameterServer::new(
            AlgorithmKind::DanaDc,
            &theta0,
            schedule(3),
            3,
            5,
        )
        .with_threads(4);
        let mut rng = crate::util::rng::Rng::new(9);
        for step in 0..60 {
            let w = (step % 3) as usize;
            let pa = a.pull(w);
            let pb = b.pull(w);
            assert_eq!(pa, pb, "sends diverged at step {step}");
            let g: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 0.1).collect();
            a.push(w, &g).unwrap();
            b.push(w, &g).unwrap();
        }
        assert_eq!(a.theta_vec(), b.theta_vec());
    }

    #[test]
    fn sliced_pull_group_counts_as_a_full_pull() {
        let k = 10;
        let ps = ShardedParameterServer::new(
            AlgorithmKind::DanaZero,
            &vec![1.0f32; k],
            schedule(1),
            1,
            3,
        );
        // pushing before the sliced group completes is still rejected
        assert!(ps.push_concurrent(0, &vec![0.1; k]).is_err());
        let ranges = ps.shard_ranges();
        let mut assembled = vec![0.0f32; k];
        for (j, r) in ranges.iter().enumerate().rev() {
            let slice = ps.pull_shard_concurrent(0, j).unwrap();
            assert_eq!(slice.len(), r.len());
            assembled[r.clone()].copy_from_slice(&slice);
            if j > 0 {
                assert!(
                    ps.push_concurrent(0, &vec![0.1; k]).is_err(),
                    "group incomplete after shard {j}"
                );
            }
        }
        assert_eq!(assembled, vec![1.0; k]);
        ps.push_concurrent(0, &vec![0.1; k]).unwrap();
        assert_eq!(ps.master_step(), 1);
    }

    #[test]
    fn pipelined_window_matches_monolithic_exactly() {
        // depth-1 windows: striped ≡ monolithic through the identical
        // pipelined pull/push sequence — sends, θ, and lag rows.
        let k = 13;
        let theta0: Vec<f32> = (0..k).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut mono = crate::server::ParameterServer::new(
            make_algorithm(AlgorithmKind::DcAsgd, &theta0, 2),
            schedule(2),
            2,
        );
        let mut shrd =
            ShardedParameterServer::new(AlgorithmKind::DcAsgd, &theta0, schedule(2), 2, 4);
        Master::set_pipeline_depth(&mut mono, 1);
        shrd.set_pipeline(1);
        mono.metrics.set_every(1);
        shrd.metrics.set_every(1);
        for w in 0..2 {
            for _ in 0..2 {
                let a = mono.pull(w).to_vec();
                let b = shrd.pull(w);
                assert_eq!(a, b, "prime pull diverged for worker {w}");
            }
        }
        let mut rng = crate::util::rng::Rng::new(41);
        for step in 0..30 {
            let w = step % 2;
            let g: Vec<f32> = (0..k).map(|_| 0.1 * rng.normal() as f32).collect();
            mono.push(w, &g).unwrap();
            shrd.push(w, &g).unwrap();
            let a = mono.pull(w).to_vec();
            let b = shrd.pull(w);
            for i in 0..k {
                assert!((a[i] - b[i]).abs() < 1e-6, "step {step} send[{i}]: {} vs {}", a[i], b[i]);
            }
        }
        let (ra, rb) = (shrd.metrics.rows(), mono.metrics.rows());
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!((x.step, x.worker, x.lag), (y.step, y.worker, y.lag));
        }
        let (a, b) = (shrd.theta_vec(), mono.theta().to_vec());
        for i in 0..k {
            assert!((a[i] - b[i]).abs() < 1e-5, "theta[{i}]: {} vs {}", a[i], b[i]);
        }
    }

    #[test]
    fn sliced_groups_fill_the_pipeline_window_in_order() {
        let k = 10;
        let ps = ShardedParameterServer::new(
            AlgorithmKind::Asgd,
            &vec![1.0f32; k],
            schedule(1),
            1,
            3,
        );
        ps.set_pipeline(1); // window cap 2
        let pull_group = |ps: &ShardedParameterServer| {
            for shard in 0..3 {
                ps.pull_shard_concurrent(0, shard).unwrap();
            }
        };
        pull_group(&ps);
        assert_eq!(ps.outstanding_pulls(0), 1);
        pull_group(&ps);
        assert_eq!(ps.outstanding_pulls(0), 2);
        pull_group(&ps); // at the cap: refreshes the newest entry
        assert_eq!(ps.outstanding_pulls(0), 2);
        ps.push_concurrent(0, &vec![0.1; k]).unwrap();
        assert_eq!(ps.outstanding_pulls(0), 1, "push consumed the oldest group");
        ps.push_concurrent(0, &vec![0.1; k]).unwrap();
        assert_eq!(ps.outstanding_pulls(0), 1, "the last entry is retained");
    }

    #[test]
    fn scrape_mirrors_track_gates_membership_and_slots() {
        let k = 8;
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::DanaZero,
            &vec![0.0f32; k],
            schedule(2),
            2,
            2,
        );
        assert_eq!(ps.worker_counts_relaxed(), (2, 2));
        assert_eq!(ps.shard_gate_stats(), vec![(0, 0), (0, 0)]);
        ps.pull(0);
        ps.push(0, &vec![1.0f32; k]).unwrap();
        assert_eq!(ps.shard_gate_stats(), vec![(1, 0), (1, 0)]);
        assert_eq!(ps.metrics.hub_handle().pushes_total(), 1);
        let table = ps.slot_table_concurrent();
        assert!(table[0].live && table[0].window == 1 && table[0].last_push == 1);
        assert!(table[1].live && table[1].window == 0 && table[1].last_push == 0);
        assert_eq!(Master::slot_stats(&ps, 0), (1, 1));
        assert_eq!(Master::slot_stats(&ps, 9), (0, 0), "unknown slot is zeros");
        ps.remove_worker(1, LeavePolicy::Retire).unwrap();
        assert_eq!(ps.worker_counts_relaxed(), (1, 2));
        assert!(!ps.slot_table_concurrent()[1].live);
        ps.add_worker();
        assert_eq!(ps.worker_counts_relaxed(), (2, 2));
        let rejoined = ps.slot_table_concurrent()[1];
        assert!(rejoined.live && rejoined.last_push == 0, "rejoin resets last push");
    }

    #[test]
    fn restore_fast_forwards_scrape_mirrors() {
        let k = 6;
        let mut a = ShardedParameterServer::new(
            AlgorithmKind::Asgd,
            &vec![1.0f32; k],
            schedule(1),
            1,
            3,
        );
        a.pull(0);
        a.push(0, &vec![0.1f32; k]).unwrap();
        a.push(0, &vec![0.1f32; k]).unwrap();
        let snap = a.snapshot_concurrent().unwrap();
        let b = ShardedParameterServer::new(
            AlgorithmKind::Asgd,
            &vec![1.0f32; k],
            schedule(1),
            1,
            3,
        );
        b.restore_concurrent(&snap).unwrap();
        assert_eq!(b.shard_gate_stats(), vec![(2, 0); 3]);
        assert_eq!(b.worker_counts_relaxed(), (1, 1));
    }

    #[test]
    fn concurrent_pushes_are_ticket_ordered_exactly() {
        // 4 threads hammer one striped server with IDENTICAL messages:
        // the ticket gates make any interleaving equal to the serial
        // trajectory bit-for-bit (same message at every step ⇒ the
        // per-step float ops are identical regardless of which thread
        // lands which ticket).  Decaying eta exercises the momentum
        // correction inside the gated region too.
        let k = 23;
        let theta0: Vec<f32> = (0..k).map(|i| (i as f32 * 0.17).cos()).collect();
        let sched = || {
            LrSchedule::new(ScheduleConfig {
                warmup_epochs: 0.0,
                decay_epochs: vec![2.0],
                decay_factor: 0.1,
                steps_per_epoch: 10,
                n_workers: 4,
                ..ScheduleConfig::default()
            })
        };
        let g = vec![0.01f32; k];
        let threads = 4usize;
        let per = 25usize;
        for kind in [AlgorithmKind::Asgd, AlgorithmKind::NagAsgd] {
            let ps = ShardedParameterServer::new(kind, &theta0, sched(), threads, 7)
                .with_threads(1);
            std::thread::scope(|s| {
                for w in 0..threads {
                    let ps = &ps;
                    let g = &g;
                    s.spawn(move || {
                        let mut buf = vec![0.0f32; k];
                        ps.pull_into_concurrent(w, &mut buf).unwrap();
                        for _ in 0..per {
                            ps.push_concurrent(w, g).unwrap();
                        }
                    });
                }
            });
            assert_eq!(ps.master_step(), (threads * per) as u64, "{kind}");
            // serial replica of the same push count
            let mut serial = ShardedParameterServer::new(kind, &theta0, sched(), 1, 7);
            serial.pull(0);
            for _ in 0..threads * per {
                serial.push(0, &g).unwrap();
            }
            assert_eq!(ps.theta_vec(), serial.theta_vec(), "{kind}: hammer diverged");
        }
    }
}

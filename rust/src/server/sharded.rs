//! Sharded, lock-striped parameter server — the master's O(k) hot path
//! split across S contiguous shards and applied in parallel.
//!
//! The paper's scaling argument (§4.1, Appendix C.1) is that the master
//! must stay O(k) per update or it becomes the bottleneck before the
//! workers do; on a multicore host the next constant-factor lever is
//! memory parallelism, so this server splits θ and *all* per-worker
//! auxiliary state — momentum vectors vᶦ, the incremental v⁰, the
//! retained `sent` copies DC-ASGD needs — into S contiguous shards, each
//! owned by an independent [`Algorithm`] instance over its coordinate
//! range.  `push`/`pull` fan the shards out over scoped threads; there is
//! no shared mutable state between shards, so no locks are taken on the
//! apply path (lock-striping degenerates to pure ownership).
//!
//! **Equivalence contract.**  Every update rule in `optim/` is elementwise
//! over its state vectors, so a shard restricted to coordinates `[a, b)`
//! performs bit-for-bit the operations the monolithic server performs on
//! those coordinates — except for whole-vector *reductions*.  Two appear
//! in the system:
//!
//! * gap/lag metrics: ‖θ−θ_sent‖ and ‖msg‖ are reduced across shards as
//!   partial sums of squares ([`crate::math::sub_norm_sq`]);
//! * YellowFin's tuner: handled by the two-phase apply protocol on the
//!   trait ([`Algorithm::apply_stats`] → merge →
//!   [`Algorithm::master_apply_with`]), which feeds every shard the same
//!   globally reduced statistics so all shard-local scalar tuner states
//!   evolve in lockstep with the monolithic instance.
//!
//! The property suite in `rust/tests/properties.rs` pins this contract for
//! all ten `AlgorithmKind`s × S ∈ {1, 2, 7, 16} to ≤1e-5 relative
//! tolerance (f64 reassociation across shard boundaries is the only
//! permitted divergence).

use super::metrics::{MetricRow, MetricsRecorder};
use super::{Master, MasterSnapshot};
use crate::math;
use crate::optim::{
    claim_slot, make_algorithm, Algorithm, AlgorithmKind, ApplyStats, LeavePolicy, LrSchedule,
    StateDict, StateVec, Step, WorkerState, ANY_SLOT,
};
use std::ops::Range;

/// Split `0..k` into `n_shards` contiguous near-equal ranges (lengths
/// differ by at most one; shard count is clamped to `max(k, 1)` so no
/// shard is empty for non-trivial k).
pub fn shard_bounds(k: usize, n_shards: usize) -> Vec<Range<usize>> {
    let s = n_shards.max(1).min(k.max(1));
    let base = k / s;
    let rem = k % s;
    let mut out = Vec::with_capacity(s);
    let mut start = 0;
    for i in 0..s {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, k);
    out
}

/// One shard: an algorithm instance over a contiguous coordinate range
/// plus the per-worker `sent` copies restricted to that range.
struct Shard {
    alg: Box<dyn Algorithm>,
    /// Parameters most recently sent to each worker, this shard's slice.
    sent: Vec<Vec<f32>>,
    range: Range<usize>,
}

/// Sharded drop-in for [`super::ParameterServer`]: same FIFO discipline,
/// same schedule/momentum-correction/metrics semantics, state split into
/// [`shard_bounds`] ranges and applied in parallel.
pub struct ShardedParameterServer {
    kind: AlgorithmKind,
    shards: Vec<Shard>,
    schedule: LrSchedule,
    /// Master step at which each worker last pulled.
    pulled_at: Vec<u64>,
    /// Whether each worker holds valid pulled parameters.
    has_pulled: Vec<bool>,
    /// Slot liveness (elastic membership), mirrored by every shard.
    live: Vec<bool>,
    master_step: u64,
    last_eta: f32,
    momentum_correction: bool,
    /// Scoped-thread fan-out width for push/pull (1 = serial).
    threads: usize,
    /// Total parameter count k.
    k: usize,
    pub metrics: MetricsRecorder,
}

impl ShardedParameterServer {
    pub fn new(
        kind: AlgorithmKind,
        theta0: &[f32],
        schedule: LrSchedule,
        n_workers: usize,
        n_shards: usize,
    ) -> Self {
        let bounds = shard_bounds(theta0.len(), n_shards);
        let shards: Vec<Shard> = bounds
            .iter()
            .map(|r| Shard {
                alg: make_algorithm(kind, &theta0[r.clone()], n_workers),
                sent: vec![vec![0.0; r.len()]; n_workers],
                range: r.clone(),
            })
            .collect();
        let last_eta = schedule.eta_at(0);
        ShardedParameterServer {
            kind,
            shards,
            schedule,
            pulled_at: vec![0; n_workers],
            has_pulled: vec![false; n_workers],
            live: vec![true; n_workers],
            master_step: 0,
            last_eta,
            momentum_correction: true,
            threads: crate::util::parallel::default_threads(),
            k: theta0.len(),
            metrics: MetricsRecorder::default(),
        }
    }

    /// Cap the scoped-thread fan-out (1 = serial shard loop; useful for
    /// benchmarking the partition overhead in isolation).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn with_momentum_correction(mut self, on: bool) -> Self {
        self.momentum_correction = on;
        self
    }

    pub fn kind(&self) -> AlgorithmKind {
        self.kind
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Worker slots ever allocated (live + retired).
    pub fn n_workers(&self) -> usize {
        self.pulled_at.len()
    }

    /// Workers currently in the cluster.
    pub fn n_live(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    pub fn worker_is_live(&self, worker: usize) -> bool {
        self.live.get(worker).copied().unwrap_or(false)
    }

    /// A worker joins: the membership change fans out across *all* shards
    /// before this returns (single `&mut self` critical section), so the
    /// sharded≡monolithic contract holds through churn — every shard
    /// allocates the same slot ([`claim_slot`] is deterministic).
    pub fn add_worker(&mut self) -> usize {
        let slot = claim_slot(&mut self.live);
        for sh in self.shards.iter_mut() {
            let alg_slot = sh.alg.add_worker();
            debug_assert!(
                alg_slot == ANY_SLOT || alg_slot == slot,
                "shard allocated slot {alg_slot}, server allocated {slot}"
            );
            if slot == sh.sent.len() {
                sh.sent.push(vec![0.0; sh.range.len()]);
            } else {
                sh.sent[slot].fill(0.0);
            }
        }
        if slot == self.pulled_at.len() {
            self.pulled_at.push(0);
            self.has_pulled.push(false);
        } else {
            self.pulled_at[slot] = 0;
            self.has_pulled[slot] = false;
        }
        slot
    }

    /// A worker leaves: retire its slot on every shard atomically (w.r.t.
    /// pushes/pulls, which also need `&mut self`).
    pub fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.worker_is_live(worker),
            "remove_worker: worker {worker} is not live (slots: {})",
            self.live.len()
        );
        self.live[worker] = false;
        self.has_pulled[worker] = false;
        for sh in self.shards.iter_mut() {
            sh.alg.remove_worker(worker, policy);
        }
        Ok(())
    }

    pub fn master_step(&self) -> u64 {
        self.master_step
    }

    pub fn param_count(&self) -> usize {
        self.k
    }

    pub fn schedule(&self) -> &LrSchedule {
        &self.schedule
    }

    /// Hyperparameters for the *current* master step.
    pub fn current_step(&self) -> Step {
        self.schedule.step_at(self.master_step)
    }

    /// Shard `i`'s algorithm instance (tests / introspection).
    pub fn shard_algorithm(&self, i: usize) -> &dyn Algorithm {
        self.shards[i].alg.as_ref()
    }

    /// Assemble the master parameters from all shards.
    pub fn theta_vec(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k];
        for sh in &self.shards {
            out[sh.range.clone()].copy_from_slice(sh.alg.theta());
        }
        out
    }

    /// Worker `worker` pulls parameters: each shard runs its algorithm's
    /// `master_send` into the retained `sent` slice, in parallel, and the
    /// slices are assembled into one contiguous vector.
    pub fn pull(&mut self, worker: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k];
        self.pull_into_buf(worker, &mut out);
        out
    }

    /// Allocation-free pull into a caller-retained k-length buffer.
    pub fn pull_into_buf(&mut self, worker: usize, out: &mut [f32]) {
        assert!(
            self.worker_is_live(worker),
            "pull for retired/unknown worker {worker}"
        );
        assert_eq!(
            out.len(),
            self.k,
            "pull buffer length {} != parameter count {}",
            out.len(),
            self.k
        );
        let s = self.schedule.step_at(self.master_step);
        {
            // Pre-split the output buffer into per-shard slots so each
            // scoped thread owns disjoint destinations.
            let mut pairs: Vec<(&mut Shard, &mut [f32])> = Vec::with_capacity(self.shards.len());
            let mut rest: &mut [f32] = out;
            for sh in self.shards.iter_mut() {
                let take = std::mem::take(&mut rest);
                let (slot, remainder) = take.split_at_mut(sh.range.len());
                pairs.push((sh, slot));
                rest = remainder;
            }
            crate::util::parallel::par_chunks_mut(&mut pairs, self.threads, |_, group| {
                for (sh, slot) in group.iter_mut() {
                    let mut buf = std::mem::take(&mut sh.sent[worker]);
                    sh.alg.master_send(worker, &mut buf, s);
                    slot.copy_from_slice(&buf);
                    sh.sent[worker] = buf;
                }
            });
        }
        self.pulled_at[worker] = self.master_step;
        self.has_pulled[worker] = true;
    }

    /// Worker `worker` delivers its message.  Mirrors the monolithic
    /// server's push exactly: schedule + momentum correction, metric tap
    /// (reduced across shards), then the (possibly two-phase) apply fanned
    /// out over shards.  Returns the [`Step`] that was applied.
    pub fn push(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        anyhow::ensure!(
            worker < self.live.len(),
            "push from unknown worker {worker} (slots: {})",
            self.live.len()
        );
        anyhow::ensure!(self.live[worker], "push from retired worker {worker}");
        anyhow::ensure!(
            self.has_pulled[worker],
            "worker {worker} pushed before ever pulling"
        );
        anyhow::ensure!(
            msg.len() == self.k,
            "message length {} != parameter count {}",
            msg.len(),
            self.k
        );
        let s = self.schedule.step_at(self.master_step);
        if self.momentum_correction && s.eta != self.last_eta && self.last_eta > 0.0 {
            let ratio = s.eta / self.last_eta;
            for sh in self.shards.iter_mut() {
                sh.alg.rescale_momentum(ratio);
            }
        }
        self.last_eta = s.eta;

        if self.metrics.wants(self.master_step) {
            let mut gap_sq = 0.0f64;
            let mut msg_sq = 0.0f64;
            for sh in &self.shards {
                gap_sq += math::sub_norm_sq(sh.alg.theta(), &sh.sent[worker]);
                msg_sq += math::norm2_sq(&msg[sh.range.clone()]);
            }
            let kf = self.k as f64;
            let gap = gap_sq.sqrt() / kf.sqrt();
            let msg_norm = msg_sq.sqrt();
            let lag = self.master_step - self.pulled_at[worker];
            self.metrics.record(MetricRow {
                step: self.master_step,
                worker,
                gap,
                norm_gap: if msg_norm > 0.0 { gap * kf.sqrt() / msg_norm } else { 0.0 },
                lag,
                eta: s.eta,
                msg_norm,
            });
        }

        // Phase 1: whole-vector statistics, reduced across shards.  Only
        // rules with global reductions (YellowFin) pay for this pass; it is
        // read-only, so it fans out like phase 2.
        let mut stats = ApplyStats::default();
        if self.shards[0].alg.needs_apply_stats() {
            let partials = crate::util::parallel::par_map(&self.shards, self.threads, |sh| {
                sh.alg.apply_stats(worker, &msg[sh.range.clone()], &sh.sent[worker])
            });
            for partial in &partials {
                stats.merge(partial);
            }
        }

        // Phase 2: elementwise apply, shards in parallel.
        crate::util::parallel::par_chunks_mut(&mut self.shards, self.threads, |_, group| {
            for sh in group.iter_mut() {
                let r = sh.range.clone();
                sh.alg.master_apply_with(worker, &msg[r], &sh.sent[worker], s, &stats);
            }
        });
        self.master_step += 1;
        Ok(s)
    }
}

impl Master for ShardedParameterServer {
    fn algo_kind(&self) -> AlgorithmKind {
        self.kind
    }

    fn workers(&self) -> usize {
        self.n_workers()
    }

    fn live_workers(&self) -> usize {
        self.n_live()
    }

    fn is_live(&self, worker: usize) -> bool {
        self.worker_is_live(worker)
    }

    fn add_worker(&mut self) -> usize {
        ShardedParameterServer::add_worker(self)
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        ShardedParameterServer::remove_worker(self, worker, policy)
    }

    fn steps_done(&self) -> u64 {
        self.master_step
    }

    fn param_len(&self) -> usize {
        self.k
    }

    fn step_now(&self) -> Step {
        self.current_step()
    }

    fn theta_vec(&self) -> Vec<f32> {
        ShardedParameterServer::theta_vec(self)
    }

    fn pull_params(&mut self, worker: usize) -> Vec<f32> {
        self.pull(worker)
    }

    fn pull_into(&mut self, worker: usize, out: &mut [f32]) {
        self.pull_into_buf(worker, out);
    }

    fn push_update(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        self.push(worker, msg)
    }

    fn make_worker_state(&self) -> WorkerState {
        // Worker state is full-length, not shard-length: size the momentum
        // buffer to k when the algorithm keeps one (DANA-Slim).  The
        // worker-side transform re-sizes on first use anyway, so this only
        // preserves the monolithic server's eager allocation.
        let mut ws = self.shards[0].alg.make_worker_state();
        if !ws.v.is_empty() {
            ws.v = vec![0.0; self.k];
        }
        ws
    }

    fn worker_transform(&self, ws: &mut WorkerState, grad: &mut [f32], s: Step) {
        // The worker half is shard-agnostic (it only touches worker-local
        // state and the full gradient), so any shard's instance serves.
        self.shards[0].alg.worker_message(ws, grad, s);
    }

    fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut MetricsRecorder {
        &mut self.metrics
    }

    /// Assemble a layout-independent snapshot: coordinate-aligned state is
    /// concatenated across shards in range order; shard-replicated scalars
    /// are taken from shard 0 (every shard's copy is identical — the
    /// membership fan-out and two-phase apply keep them in lockstep).
    fn snapshot(&self) -> anyhow::Result<MasterSnapshot> {
        let n = self.n_workers();
        let mut sent: Vec<Vec<f32>> = vec![Vec::with_capacity(self.k); n];
        let mut state: StateDict = Vec::new();
        for (si, sh) in self.shards.iter().enumerate() {
            for (w, out) in sent.iter_mut().enumerate() {
                out.extend_from_slice(&sh.sent[w]);
            }
            let piece = sh.alg.state_dict();
            if si == 0 {
                state = piece;
                continue;
            }
            anyhow::ensure!(
                piece.len() == state.len(),
                "shard {si} state entry count {} != shard 0's {}",
                piece.len(),
                state.len()
            );
            for ((name, acc), (pname, pval)) in state.iter_mut().zip(piece) {
                anyhow::ensure!(
                    *name == pname,
                    "shard {si} state entry {pname:?} != shard 0's {name:?}"
                );
                match (acc, pval) {
                    (StateVec::Coord(a), StateVec::Coord(b)) => a.extend_from_slice(&b),
                    (StateVec::PerWorker(a), StateVec::PerWorker(b)) => {
                        anyhow::ensure!(
                            a.len() == b.len(),
                            "shard {si} state {name:?}: slot count mismatch"
                        );
                        for (av, bv) in a.iter_mut().zip(b) {
                            av.extend_from_slice(&bv);
                        }
                    }
                    (StateVec::Scalars(_), StateVec::Scalars(_)) => {}
                    _ => anyhow::bail!("shard {si} state {name:?}: shape mismatch"),
                }
            }
        }
        Ok(MasterSnapshot {
            kind: self.kind,
            master_step: self.master_step,
            last_eta: self.last_eta,
            theta: ShardedParameterServer::theta_vec(self),
            live: self.live.clone(),
            sent,
            pulled_at: self.pulled_at.clone(),
            has_pulled: self.has_pulled.clone(),
            state,
        })
    }

    fn restore(&mut self, snap: &MasterSnapshot) -> anyhow::Result<()> {
        snap.validate(self.kind, self.k)?;
        anyhow::ensure!(
            self.master_step == 0 && self.n_live() == self.n_workers(),
            "restore target must be freshly constructed"
        );
        anyhow::ensure!(
            self.n_workers() <= snap.slots(),
            "restore target has {} slots, snapshot only {}",
            self.n_workers(),
            snap.slots()
        );
        while self.n_workers() < snap.slots() {
            ShardedParameterServer::add_worker(self);
        }
        for (w, &alive) in snap.live.iter().enumerate() {
            if !alive {
                ShardedParameterServer::remove_worker(self, w, LeavePolicy::Retire)?;
            }
        }
        for sh in self.shards.iter_mut() {
            let r = sh.range.clone();
            sh.alg.set_theta(&snap.theta[r.clone()]);
            // Slice the full-length dict down to this shard's range;
            // scalars broadcast verbatim.
            let local: StateDict = snap
                .state
                .iter()
                .map(|(name, val)| {
                    let v = match val {
                        StateVec::Coord(v) => StateVec::Coord(v[r.clone()].to_vec()),
                        StateVec::PerWorker(vs) => StateVec::PerWorker(
                            vs.iter().map(|v| v[r.clone()].to_vec()).collect(),
                        ),
                        StateVec::Scalars(s) => StateVec::Scalars(s.clone()),
                    };
                    (name.clone(), v)
                })
                .collect();
            sh.alg.load_state_dict(&local)?;
            for (w, full) in snap.sent.iter().enumerate() {
                sh.sent[w] = full[r.clone()].to_vec();
            }
        }
        self.pulled_at = snap.pulled_at.clone();
        self.has_pulled = snap.has_pulled.clone();
        self.master_step = snap.master_step;
        self.last_eta = snap.last_eta;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ScheduleConfig;

    fn schedule(n: usize) -> LrSchedule {
        LrSchedule::new(ScheduleConfig {
            warmup_epochs: 0.0,
            decay_epochs: vec![],
            steps_per_epoch: 10,
            n_workers: n,
            ..ScheduleConfig::default()
        })
    }

    // shard_bounds partition invariants are pinned by the randomized
    // property `prop_shard_bounds_partition` in rust/tests/properties.rs.

    #[test]
    fn pull_push_cycle_advances_master() {
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::Asgd,
            &[1.0f32; 10],
            schedule(2),
            2,
            3,
        );
        let p = ps.pull(0);
        assert_eq!(p, vec![1.0; 10]);
        ps.push(0, &[1.0; 10]).unwrap();
        assert_eq!(ps.master_step(), 1);
        assert!(ps.theta_vec()[0] < 1.0);
        assert_eq!(ps.n_shards(), 3);
    }

    #[test]
    fn push_without_pull_is_recoverable_error() {
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::Asgd,
            &[1.0f32; 4],
            schedule(2),
            2,
            2,
        );
        let err = ps.push(1, &[0.0; 4]).unwrap_err();
        assert!(err.to_string().contains("pushed before ever pulling"));
        ps.pull(1);
        ps.push(1, &[0.0; 4]).unwrap();
    }

    #[test]
    fn membership_fans_out_across_all_shards() {
        let k = 9;
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::DanaZero,
            &vec![0.0f32; k],
            schedule(2),
            2,
            4,
        );
        ps.pull(0);
        ps.push(0, &vec![1.0f32; k]).unwrap();
        // worker 0 leaves (retire): every shard's v⁰ slice drops its vᶦ,
        // so a fresh pull equals plain theta again (zero look-ahead).
        ps.remove_worker(0, LeavePolicy::Retire).unwrap();
        assert_eq!(ps.n_live(), 1);
        assert!(ps.push(0, &vec![1.0f32; k]).is_err(), "retired push rejected");
        let hat = ps.pull(1);
        assert_eq!(hat, ps.theta_vec(), "v0 retired on every shard");
        // rejoin reuses slot 0 on every shard
        assert_eq!(ps.add_worker(), 0);
        let p = ps.pull(0);
        assert_eq!(p.len(), k);
        ps.push(0, &vec![0.5f32; k]).unwrap();
    }

    #[test]
    fn shard_count_clamps_to_k() {
        let ps = ShardedParameterServer::new(
            AlgorithmKind::DanaZero,
            &[0.5f32; 3],
            schedule(1),
            1,
            16,
        );
        assert_eq!(ps.n_shards(), 3);
        assert_eq!(ps.theta_vec(), vec![0.5; 3]);
    }

    #[test]
    fn dana_lookahead_send_spans_shards() {
        // After one update the look-ahead hat differs from theta on every
        // coordinate, including across shard boundaries.
        let k = 9;
        let mut ps = ShardedParameterServer::new(
            AlgorithmKind::DanaZero,
            &vec![0.0f32; k],
            schedule(2),
            2,
            4,
        );
        ps.pull(0);
        ps.push(0, &vec![1.0f32; k]).unwrap();
        let theta = ps.theta_vec();
        let hat = ps.pull(1);
        for i in 0..k {
            assert!(
                (theta[i] - hat[i]).abs() > 0.0,
                "coordinate {i}: look-ahead did not differ"
            );
        }
    }

    #[test]
    fn serial_and_threaded_fanout_agree() {
        let k = 37;
        let theta0: Vec<f32> = (0..k).map(|i| (i as f32 * 0.3).sin()).collect();
        let mut a = ShardedParameterServer::new(
            AlgorithmKind::DanaDc,
            &theta0,
            schedule(3),
            3,
            5,
        )
        .with_threads(1);
        let mut b = ShardedParameterServer::new(
            AlgorithmKind::DanaDc,
            &theta0,
            schedule(3),
            3,
            5,
        )
        .with_threads(4);
        let mut rng = crate::util::rng::Rng::new(9);
        for step in 0..60 {
            let w = (step % 3) as usize;
            let pa = a.pull(w);
            let pb = b.pull(w);
            assert_eq!(pa, pb, "sends diverged at step {step}");
            let g: Vec<f32> = (0..k).map(|_| rng.normal() as f32 * 0.1).collect();
            a.push(w, &g).unwrap();
            b.push(w, &g).unwrap();
        }
        assert_eq!(a.theta_vec(), b.theta_vec());
    }
}

//! The asynchronous parameter server (master).
//!
//! Owns the master parameters through a boxed [`Algorithm`], the learning-
//! rate schedule, and — because the *gap* (Section 3) is the paper's central
//! measurement — the instrumentation taps: for every applied update it can
//! record the lag τ (updates from other workers since this worker's pull)
//! and the gap `G(Δ) = ‖θ_now − θ_sent‖₂/√k` between the parameters the
//! gradient was computed on and the parameters it lands on.
//!
//! The master scheme is a plain FIFO, exactly as the paper's Appendix A.1
//! states; callers (the simulated or real-async trainers) deliver updates in
//! completion order via [`ParameterServer::push`].

pub mod metrics;
pub mod sharded;

use crate::optim::{
    claim_slot, make_algorithm, Algorithm, AlgorithmKind, ApplyStats, LeavePolicy, LrSchedule,
    StateDict, Step, WorkerState, ANY_SLOT,
};
use crate::util::sync;
use metrics::{MetricRow, MetricsHub, MetricsRecorder};
pub use sharded::{shard_bounds, ShardedParameterServer};
use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// A complete, restorable image of a master's training state: θ, the
/// algorithm's auxiliary state ([`StateDict`]), slot liveness, the per-slot
/// pull-window bookkeeping, and the step counter.  The schedule is NOT part
/// of the snapshot — it is reconstructed from the serve configuration at
/// resume time (resuming under different flags is a config error the
/// checkpoint header checks guard against).
///
/// Layout-independent: a snapshot taken from a monolithic server restores
/// into a sharded one (and vice versa, or across different shard counts) —
/// coordinate-aligned state is stored full-length and sliced by
/// [`shard_bounds`] on the way back in.
#[derive(Debug, Clone, PartialEq)]
pub struct MasterSnapshot {
    pub kind: AlgorithmKind,
    pub master_step: u64,
    pub last_eta: f32,
    pub theta: Vec<f32>,
    /// Slot liveness; length is the slot high-water mark.
    pub live: Vec<bool>,
    /// Per-slot pull window, oldest first: `(master step at pull, the
    /// parameters that were sent)`.  The front entry is what the slot's
    /// next push is judged against (gap, lag, DC-ASGD's θ_sent); depth >
    /// 1 appears only under a pipelined driver (`--pipeline-depth D`
    /// keeps up to D+1 pulls outstanding per worker).
    pub pulls: Vec<Vec<(u64, Vec<f32>)>>,
    /// The algorithm's [`crate::optim::Algorithm::state_dict`].
    pub state: StateDict,
}

impl MasterSnapshot {
    /// Number of worker slots (live + retired) in the snapshot.
    pub fn slots(&self) -> usize {
        self.live.len()
    }

    /// Internal-consistency + compatibility check against the restoring
    /// server's algorithm kind and parameter count.  Fails closed.
    pub fn validate(&self, kind: AlgorithmKind, k: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.kind == kind,
            "snapshot is for {} but the server runs {}",
            self.kind.name(),
            kind.name()
        );
        anyhow::ensure!(
            self.theta.len() == k,
            "snapshot k={} but the server has k={k}",
            self.theta.len()
        );
        let n = self.live.len();
        anyhow::ensure!(
            self.pulls.len() == n,
            "snapshot slot arrays disagree: live={n} pulls={}",
            self.pulls.len()
        );
        for (w, q) in self.pulls.iter().enumerate() {
            anyhow::ensure!(
                q.len() <= MAX_PULL_WINDOW,
                "snapshot pulls[{w}] window {} exceeds the cap {MAX_PULL_WINDOW}",
                q.len()
            );
            for (i, (_, p)) in q.iter().enumerate() {
                anyhow::ensure!(
                    p.len() == k,
                    "snapshot pulls[{w}][{i}] length {} != k {k}",
                    p.len()
                );
            }
        }
        Ok(())
    }
}

/// Hard ceiling on the per-slot pull window (pipeline depth + 1): bounds
/// server memory against a malicious or misconfigured client no matter
/// what depth it claims, and gives checkpoint validation a sane bound.
pub const MAX_PULL_WINDOW: usize = 33;

/// Unified interface over the monolithic and sharded masters, so trainers
/// are generic over the server layout.  Method names are distinct from the
/// concrete servers' inherent methods (which keep their richer signatures,
/// e.g. [`ParameterServer::pull`] returning a borrowed slice).
///
/// Membership is dynamic: [`Master::add_worker`] / [`Master::remove_worker`]
/// grow and retire worker slots mid-run.  `workers()` counts *slots* (the
/// high-water capacity); `live_workers()` counts the current cluster.
pub trait Master: Send {
    fn algo_kind(&self) -> AlgorithmKind;
    /// Worker slots ever allocated (live + retired).
    fn workers(&self) -> usize;
    /// Workers currently in the cluster.
    fn live_workers(&self) -> usize;
    /// Whether `worker` is a live slot.
    fn is_live(&self, worker: usize) -> bool;
    /// A worker joins: allocate (or recycle) a slot across the whole
    /// server state and return its id.
    fn add_worker(&mut self) -> usize;
    /// A worker leaves: retire its slot; `policy` decides the fate of its
    /// momentum.  Errors when `worker` is not live.
    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()>;
    /// Master steps applied so far.
    fn steps_done(&self) -> u64;
    /// Total parameter count k.
    fn param_len(&self) -> usize;
    /// Hyperparameters for the current master step.
    fn step_now(&self) -> Step;
    /// Master parameters assembled into one owned vector (for eval).
    fn theta_vec(&self) -> Vec<f32>;
    /// Worker pulls parameters (owned copy of what the algorithm sends).
    fn pull_params(&mut self, worker: usize) -> Vec<f32>;
    /// Worker pulls parameters into a caller-retained buffer (the sim
    /// trainer's hot loop reuses one k-length buffer per worker instead of
    /// allocating every master step).
    fn pull_into(&mut self, worker: usize, out: &mut [f32]);
    /// Worker delivers its message; returns the applied [`Step`].  A push
    /// from an unknown or retired slot — a straggler whose update was in
    /// flight when it left — is a *recoverable* error: the server state is
    /// untouched and the caller may simply drop the message.
    fn push_update(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step>;
    /// Phase 1 of a two-phase push: the additive [`ApplyStats`] partials
    /// this update would produce, *without applying anything* (read-only
    /// on the training state).  A fan-out client stages against every
    /// server hosting a slice of the model, sums the partials (every
    /// field is a plain coordinate sum), and commits with
    /// [`Self::push_update_with`] — which is how YellowFin's whole-vector
    /// tuner reductions stay exact across a placement split.  Masters
    /// that cannot stage (there is only the single-phase apply) error.
    fn push_stats(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<ApplyStats> {
        let _ = (worker, msg);
        anyhow::bail!("this master does not support staged apply statistics")
    }
    /// Phase 2 of a two-phase push: exactly [`Self::push_update`], but
    /// applying under the caller's globally-summed statistics instead of
    /// statistics computed over this master's own coordinates.
    fn push_update_with(
        &mut self,
        worker: usize,
        msg: &[f32],
        stats: &ApplyStats,
    ) -> anyhow::Result<Step> {
        let _ = (worker, msg, stats);
        anyhow::bail!("this master does not support staged apply statistics")
    }
    /// Configure the pipeline window: each worker will keep `depth + 1`
    /// pulls outstanding (the `--pipeline-depth` of the driver).  Local
    /// masters size their per-slot pull windows and forward the staleness
    /// hint to the algorithm ([`crate::optim::Algorithm::set_staleness_hint`]);
    /// a remote master switches its push path to deferred-ack harvesting.
    /// `depth = 0` (the default) MUST leave behavior bit-for-bit unchanged.
    fn set_pipeline_depth(&mut self, depth: usize) {
        let _ = depth;
    }
    /// Settle every in-flight deferred acknowledgement (pipelined remote
    /// masters): after this returns, every push issued so far has been
    /// applied and acknowledged, so a θ read observes all of them.  No-op
    /// for local masters (pushes apply synchronously).
    fn drain_inflight(&mut self) -> anyhow::Result<()> {
        Ok(())
    }
    /// Fresh worker-local optimizer state.
    fn make_worker_state(&self) -> WorkerState;
    /// Worker-side message transform (DANA-Slim's local momentum).
    fn worker_transform(&self, ws: &mut WorkerState, grad: &mut [f32], s: Step);
    fn metrics(&self) -> &MetricsRecorder;
    fn metrics_mut(&mut self) -> &mut MetricsRecorder;
    /// Pushes this master knows were lost in transit: deferred-push
    /// acknowledgements a [`crate::net::RemoteMaster`] abandoned on
    /// reconnect.  Always 0 for local masters (pushes apply
    /// synchronously, nothing can be lost between push and ack).
    fn pushes_lost(&self) -> u64 {
        0
    }
    /// Per-placement-group `(endpoint, master steps done)` rows for
    /// fan-out masters (one row per server in the placement; the step
    /// count is read fresh from each server).  Empty for masters with a
    /// single home.  `&mut self` because reading fresh counts may take a
    /// control round trip per group.
    fn placement_groups(&mut self) -> Vec<(String, u64)> {
        Vec::new()
    }
    /// Per-slot scrape row: `(outstanding pull-window depth, master step
    /// count right after the slot's last applied push — 0 = never
    /// pushed)`.  Masters that do not track the table report `(0, 0)`.
    fn slot_stats(&self, worker: usize) -> (usize, u64) {
        let _ = worker;
        (0, 0)
    }
    /// A complete restorable image of the training state (fault
    /// tolerance).  Errors for masters that hold no local state (a
    /// [`crate::net::RemoteMaster`] checkpoints server-side).
    fn snapshot(&self) -> anyhow::Result<MasterSnapshot>;
    /// Restore a [`Self::snapshot`] image onto a freshly constructed
    /// server (no steps applied, no membership changes yet) of the same
    /// algorithm kind and parameter count.  Grows/retires slots to match
    /// the snapshot, then overwrites θ, algorithm state and bookkeeping.
    fn restore(&mut self, snap: &MasterSnapshot) -> anyhow::Result<()>;
}

/// The `&self` interface a transport server drives a master through, from
/// many connection threads at once.  Two implementations:
///
/// * [`LockedMaster`] — any [`Master`] behind one process-wide mutex: the
///   PR 3 serving path, kept as the simple/reference backend (strict FIFO
///   falls out of lock-acquisition order);
/// * [`ShardedParameterServer`] — natively concurrent: per-shard locks,
///   ticket-ordered applies, membership under an epoch lock.  Any thread
///   interleaving is bit-for-bit equivalent to the FIFO of its ticket
///   order, which `rust/tests/striped.rs` pins against the locked path.
///
/// Setup-time methods (`restore`, `set_metrics_every`) take `&mut self`:
/// they run before the server is shared with connection threads.
pub trait ServingMaster: Send + Sync {
    fn algo_kind(&self) -> AlgorithmKind;
    fn param_len(&self) -> usize;
    /// Shards the serving layer may slice pulls/pushes by (1 = unsliced).
    fn shard_count(&self) -> usize;
    /// The contiguous coordinate range of each shard, in order.
    fn shard_ranges(&self) -> Vec<Range<usize>>;
    fn steps_done(&self) -> u64;
    /// One consistent `(master_step, schedule point, live workers, worker
    /// slots)` read — reply headers are built from this.
    fn status(&self) -> (u64, Step, usize, usize);
    fn is_live(&self, worker: usize) -> bool;
    /// A worker joins (see [`Master::add_worker`]).
    fn join(&self) -> usize;
    /// A worker leaves (see [`Master::remove_worker`]).
    fn leave(&self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()>;
    /// Full-length pull.  Errors (rather than panicking) for a retired
    /// slot — over the wire that is a racy-but-recoverable condition.
    fn pull(&self, worker: usize) -> anyhow::Result<Vec<f32>>;
    /// Full-length pull into a caller-retained buffer — the serving loop
    /// keeps one scratch vector per connection so the reply hot path
    /// allocates nothing (DESIGN.md §15).  `out` is resized to k; on
    /// error its contents are unspecified.  Default delegates to
    /// [`Self::pull`] for backends without an in-place path.
    fn pull_into(&self, worker: usize, out: &mut Vec<f32>) -> anyhow::Result<()> {
        *out = self.pull(worker)?;
        Ok(())
    }
    /// One shard's slice of a pull (wire `PullShard`).
    fn pull_shard(&self, worker: usize, shard: usize) -> anyhow::Result<Vec<f32>>;
    /// Sharded pull into a caller-retained buffer (see [`Self::pull_into`]).
    fn pull_shard_into(
        &self,
        worker: usize,
        shard: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        *out = self.pull_shard(worker, shard)?;
        Ok(())
    }
    /// Apply a push; returns the applied [`Step`] and the master step the
    /// update *settled as* (its ticket — exact even under concurrency),
    /// which `PushAck` reports back to pipelined clients.
    fn push(&self, worker: usize, msg: &[f32]) -> anyhow::Result<(Step, u64)>;
    /// Phase 1 of the cluster's two-phase push (wire `PushStage`): the
    /// additive [`ApplyStats`] partials over this server's coordinates,
    /// without applying anything.  See [`Master::push_stats`].
    fn push_stats(&self, worker: usize, msg: &[f32]) -> anyhow::Result<ApplyStats>;
    /// Phase 2 (wire `PushCommit`): apply one push under the caller's
    /// globally-summed statistics.  Same contract as [`Self::push`].
    fn push_with_stats(
        &self,
        worker: usize,
        msg: &[f32],
        stats: &ApplyStats,
    ) -> anyhow::Result<(Step, u64)>;
    fn theta(&self) -> Vec<f32>;
    fn snapshot(&self) -> anyhow::Result<MasterSnapshot>;
    fn restore(&mut self, snap: &MasterSnapshot) -> anyhow::Result<()>;
    fn set_metrics_every(&mut self, every: u64);
    /// Setup-time pipeline hint (`dana serve --pipeline-depth`): sizes the
    /// per-slot pull windows and forwards the staleness hint to the
    /// algorithm.  Runs before the server is shared with connections.
    fn set_pipeline_hint(&mut self, depth: usize);
    /// Handle to the lock-free metric sources (push counter, gap/lag
    /// histograms) for a scrape endpoint.  The handle is an `Arc` of
    /// atomics: reading it never contends with the push hot path.
    fn metrics_hub(&self) -> Arc<MetricsHub>;
    /// `(live workers, worker slots)` from atomic membership mirrors —
    /// scrape-safe: never takes a lock the data path wants.  May lag a
    /// concurrent join/leave by one scrape, which monitoring tolerates.
    fn worker_counts(&self) -> (usize, usize);
    /// Per-shard `(applied ticket position, issued-but-unapplied ticket
    /// backlog)` for lock-striped backends, read from atomic mirrors of
    /// the ticket gates.  Empty when the backend has no shard gates (the
    /// global-lock path applies synchronously under its mutex).
    fn shard_gates(&self) -> Vec<(u64, u64)> {
        Vec::new()
    }
    /// Per-slot `/status` table rows.  Unlike the `/metrics` accessors
    /// this may take short per-slot locks (never the whole-master or
    /// sequencer locks on the striped backend).
    fn slot_table(&self) -> Vec<SlotStatus> {
        let (_, _, _, slots) = self.status();
        (0..slots)
            .map(|w| SlotStatus { live: self.is_live(w), window: 0, last_push: 0 })
            .collect()
    }
}

/// One `/status` row for a worker slot (the wire generation is tracked by
/// the transport layer and joined in there).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SlotStatus {
    pub live: bool,
    /// Outstanding pull-window occupancy (owed window depth).
    pub window: usize,
    /// Master step count right after the slot's last applied push
    /// (0 = never pushed; a push settling as step t records t+1).
    pub last_push: u64,
}

/// Any [`Master`] behind one mutex — the global-lock serving backend.
/// Every request serializes on the lock; the master's own sharded apply
/// fan-out (if it is a [`ShardedParameterServer`]) still runs inside it.
pub struct LockedMaster {
    inner: Mutex<Box<dyn Master>>,
    /// Shard count for slice-framed requests (the inner master's S, or 1).
    shards: usize,
    /// Per-worker open slice-framed pull group: ONE inner full pull per
    /// group, sliced locally, so the inner pull-window accounting sees one
    /// pull per completed group — matching the striped backend instead of
    /// the pre-pipeline behavior of one full pull per slice.
    sliced: Mutex<Vec<Option<SliceGroup>>>,
    /// Lock-free handle to the inner master's metric hub, captured at
    /// construction so a scrape never has to take the master mutex.
    hub: Arc<MetricsHub>,
    /// Atomic membership mirrors for [`ServingMaster::worker_counts`]:
    /// refreshed under the master mutex on every join/leave/restore, read
    /// without it on the scrape path.
    live_mirror: AtomicUsize,
    slots_mirror: AtomicUsize,
}

struct SliceGroup {
    fetched: Vec<bool>,
    full: Vec<f32>,
}

impl LockedMaster {
    pub fn new(inner: Box<dyn Master>) -> Self {
        Self::with_shards(inner, 1)
    }

    /// Like [`Self::new`], declaring the inner master's shard count so
    /// slice-framed clients can address it (the lock still serializes).
    pub fn with_shards(inner: Box<dyn Master>, shards: usize) -> Self {
        let hub = inner.metrics().hub_handle();
        let live = inner.live_workers();
        let slots = inner.workers();
        LockedMaster {
            inner: Mutex::new(inner),
            shards: shards.max(1),
            sliced: Mutex::new(Vec::new()),
            hub,
            live_mirror: AtomicUsize::new(live),
            slots_mirror: AtomicUsize::new(slots),
        }
    }

    /// Refresh the membership mirrors; call with the master lock held
    /// right after any membership change so the mirrors stay exact.
    fn refresh_mirrors(&self, m: &dyn Master) {
        self.live_mirror.store(m.live_workers(), Ordering::Relaxed);
        self.slots_mirror.store(m.workers(), Ordering::Relaxed);
    }

    /// Drop any open slice group for `worker` (full pull, join, leave —
    /// a stale half-group must never serve a slot's next incarnation).
    fn clear_group(&self, worker: usize) {
        let mut groups = sync::lock(&self.sliced);
        if let Some(g) = groups.get_mut(worker) {
            *g = None;
        }
    }
}

impl ServingMaster for LockedMaster {
    fn algo_kind(&self) -> AlgorithmKind {
        sync::lock(&self.inner).algo_kind()
    }

    fn param_len(&self) -> usize {
        sync::lock(&self.inner).param_len()
    }

    fn shard_count(&self) -> usize {
        // shard_bounds clamps to k; advertise what shard_ranges() really
        // has so HelloAck can never name a shard that does not exist
        self.shard_ranges().len()
    }

    fn shard_ranges(&self) -> Vec<Range<usize>> {
        shard_bounds(self.param_len(), self.shards)
    }

    fn steps_done(&self) -> u64 {
        sync::lock(&self.inner).steps_done()
    }

    fn status(&self) -> (u64, Step, usize, usize) {
        let m = sync::lock(&self.inner);
        (m.steps_done(), m.step_now(), m.live_workers(), m.workers())
    }

    fn is_live(&self, worker: usize) -> bool {
        sync::lock(&self.inner).is_live(worker)
    }

    fn join(&self) -> usize {
        let slot = {
            let mut m = sync::lock(&self.inner);
            let slot = m.add_worker();
            self.refresh_mirrors(m.as_ref());
            slot
        };
        self.clear_group(slot);
        slot
    }

    fn leave(&self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        self.clear_group(worker);
        let mut m = sync::lock(&self.inner);
        let res = m.remove_worker(worker, policy);
        self.refresh_mirrors(m.as_ref());
        res
    }

    fn pull(&self, worker: usize) -> anyhow::Result<Vec<f32>> {
        // a full pull supersedes any half-finished sliced group
        self.clear_group(worker);
        let mut m = sync::lock(&self.inner);
        // the in-process pull contract panics for a retired slot; convert
        // to the serving contract (recoverable error) before delegating
        anyhow::ensure!(m.is_live(worker), "pull for retired/unknown worker {worker}");
        Ok(m.pull_params(worker))
    }

    fn pull_into(&self, worker: usize, out: &mut Vec<f32>) -> anyhow::Result<()> {
        self.clear_group(worker);
        let mut m = sync::lock(&self.inner);
        anyhow::ensure!(m.is_live(worker), "pull for retired/unknown worker {worker}");
        out.resize(m.param_len(), 0.0);
        m.pull_into(worker, out);
        Ok(())
    }

    /// Reference-backend sliced pull: the first slice of a group performs
    /// ONE inner full pull and caches it; the remaining slices are cut
    /// from the cache, so the inner pull-window accounting counts one
    /// pull per group exactly like the striped backend.  The cached
    /// slices are a point-in-time snapshot — pushes interleaving within
    /// a group are reflected on the striped backend's later slices but
    /// not here, which is the same cross-slice staleness a pull already
    /// tolerates (DESIGN.md §9); serial driving is bit-for-bit equal.
    fn pull_shard(&self, worker: usize, shard: usize) -> anyhow::Result<Vec<f32>> {
        let mut out = Vec::new();
        self.pull_shard_into(worker, shard, &mut out)?;
        Ok(out)
    }

    fn pull_shard_into(
        &self,
        worker: usize,
        shard: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        let mut m = sync::lock(&self.inner);
        anyhow::ensure!(m.is_live(worker), "pull for retired/unknown worker {worker}");
        let ranges = shard_bounds(m.param_len(), self.shards);
        let r = ranges
            .get(shard)
            .ok_or_else(|| anyhow::anyhow!("pull for shard {shard} of {}", ranges.len()))?
            .clone();
        let mut groups = sync::lock(&self.sliced);
        if groups.len() <= worker {
            groups.resize_with(worker + 1, || None);
        }
        if groups[worker].is_none() {
            groups[worker] = Some(SliceGroup {
                fetched: vec![false; ranges.len()],
                full: m.pull_params(worker),
            });
        }
        let complete = {
            let g = groups[worker].as_mut().expect("just ensured");
            g.fetched[shard] = true;
            out.clear();
            out.extend_from_slice(&g.full[r]);
            g.fetched.iter().all(|&f| f)
        };
        if complete {
            groups[worker] = None;
        }
        Ok(())
    }

    fn push(&self, worker: usize, msg: &[f32]) -> anyhow::Result<(Step, u64)> {
        let mut m = sync::lock(&self.inner);
        let settled = m.steps_done();
        let s = m.push_update(worker, msg)?;
        Ok((s, settled))
    }

    fn push_stats(&self, worker: usize, msg: &[f32]) -> anyhow::Result<ApplyStats> {
        sync::lock(&self.inner).push_stats(worker, msg)
    }

    fn push_with_stats(
        &self,
        worker: usize,
        msg: &[f32],
        stats: &ApplyStats,
    ) -> anyhow::Result<(Step, u64)> {
        let mut m = sync::lock(&self.inner);
        let settled = m.steps_done();
        let s = m.push_update_with(worker, msg, stats)?;
        Ok((s, settled))
    }

    fn theta(&self) -> Vec<f32> {
        sync::lock(&self.inner).theta_vec()
    }

    fn snapshot(&self) -> anyhow::Result<MasterSnapshot> {
        sync::lock(&self.inner).snapshot()
    }

    fn restore(&mut self, snap: &MasterSnapshot) -> anyhow::Result<()> {
        let mut m = sync::lock(&self.inner);
        let res = m.restore(snap);
        self.refresh_mirrors(m.as_ref());
        res
    }

    fn set_metrics_every(&mut self, every: u64) {
        sync::lock(&self.inner).metrics_mut().set_every(every);
    }

    fn set_pipeline_hint(&mut self, depth: usize) {
        sync::lock(&self.inner).set_pipeline_depth(depth);
    }

    fn metrics_hub(&self) -> Arc<MetricsHub> {
        Arc::clone(&self.hub)
    }

    fn worker_counts(&self) -> (usize, usize) {
        (
            self.live_mirror.load(Ordering::Relaxed),
            self.slots_mirror.load(Ordering::Relaxed),
        )
    }

    fn slot_table(&self) -> Vec<SlotStatus> {
        let m = sync::lock(&self.inner);
        (0..m.workers())
            .map(|w| {
                let (window, last_push) = m.slot_stats(w);
                SlotStatus { live: m.is_live(w), window, last_push }
            })
            .collect()
    }
}

impl ServingMaster for ShardedParameterServer {
    fn algo_kind(&self) -> AlgorithmKind {
        self.kind()
    }

    fn param_len(&self) -> usize {
        self.param_count()
    }

    fn shard_count(&self) -> usize {
        self.n_shards()
    }

    fn shard_ranges(&self) -> Vec<Range<usize>> {
        ShardedParameterServer::shard_ranges(self)
    }

    fn steps_done(&self) -> u64 {
        self.master_step()
    }

    fn status(&self) -> (u64, Step, usize, usize) {
        self.status_concurrent()
    }

    fn is_live(&self, worker: usize) -> bool {
        self.worker_is_live(worker)
    }

    fn join(&self) -> usize {
        self.add_worker_concurrent()
    }

    fn leave(&self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        self.remove_worker_concurrent(worker, policy)
    }

    fn pull(&self, worker: usize) -> anyhow::Result<Vec<f32>> {
        self.pull_concurrent(worker)
    }

    fn pull_into(&self, worker: usize, out: &mut Vec<f32>) -> anyhow::Result<()> {
        out.resize(self.param_count(), 0.0);
        self.pull_into_concurrent(worker, out)
    }

    fn pull_shard(&self, worker: usize, shard: usize) -> anyhow::Result<Vec<f32>> {
        self.pull_shard_concurrent(worker, shard)
    }

    fn pull_shard_into(
        &self,
        worker: usize,
        shard: usize,
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        self.pull_shard_into_concurrent(worker, shard, out)
    }

    fn push(&self, worker: usize, msg: &[f32]) -> anyhow::Result<(Step, u64)> {
        self.push_concurrent(worker, msg)
    }

    fn push_stats(&self, worker: usize, msg: &[f32]) -> anyhow::Result<ApplyStats> {
        self.push_stats_concurrent(worker, msg)
    }

    fn push_with_stats(
        &self,
        worker: usize,
        msg: &[f32],
        stats: &ApplyStats,
    ) -> anyhow::Result<(Step, u64)> {
        self.push_concurrent_with(worker, msg, Some(stats))
    }

    fn theta(&self) -> Vec<f32> {
        self.theta_vec()
    }

    fn snapshot(&self) -> anyhow::Result<MasterSnapshot> {
        self.snapshot_concurrent()
    }

    fn restore(&mut self, snap: &MasterSnapshot) -> anyhow::Result<()> {
        self.restore_concurrent(snap)
    }

    fn set_metrics_every(&mut self, every: u64) {
        self.metrics.set_every(every);
    }

    fn set_pipeline_hint(&mut self, depth: usize) {
        self.set_pipeline(depth);
    }

    fn metrics_hub(&self) -> Arc<MetricsHub> {
        self.metrics.hub_handle()
    }

    fn worker_counts(&self) -> (usize, usize) {
        self.worker_counts_relaxed()
    }

    fn shard_gates(&self) -> Vec<(u64, u64)> {
        self.shard_gate_stats()
    }

    fn slot_table(&self) -> Vec<SlotStatus> {
        self.slot_table_concurrent()
    }
}

/// Build the master a transport server hosts: lock-striped (shards are
/// the unit of concurrency wire-to-apply) when `striped`, else the
/// global-lock backend over [`make_master`]'s layout choice.
pub fn make_serving_master(
    kind: AlgorithmKind,
    theta0: &[f32],
    schedule: LrSchedule,
    n_workers: usize,
    n_shards: usize,
    threads: usize,
    striped: bool,
) -> Box<dyn ServingMaster> {
    if striped {
        Box::new(
            ShardedParameterServer::new(kind, theta0, schedule, n_workers, n_shards)
                .with_threads(threads),
        )
    } else {
        Box::new(LockedMaster::with_shards(
            make_master(kind, theta0, schedule, n_workers, n_shards, threads),
            n_shards.max(1),
        ))
    }
}

/// Build a master: monolithic for `n_shards <= 1`, sharded otherwise with
/// the apply fan-out capped at `threads`.
pub fn make_master(
    kind: AlgorithmKind,
    theta0: &[f32],
    schedule: LrSchedule,
    n_workers: usize,
    n_shards: usize,
    threads: usize,
) -> Box<dyn Master> {
    if n_shards <= 1 {
        Box::new(ParameterServer::new(
            make_algorithm(kind, theta0, n_workers),
            schedule,
            n_workers,
        ))
    } else {
        Box::new(
            ShardedParameterServer::new(kind, theta0, schedule, n_workers, n_shards)
                .with_threads(threads),
        )
    }
}

/// One retained pull: the master step it happened at and the parameters
/// that were sent (gap/lag accounting + DC-ASGD's θ_sent).
#[derive(Debug, Clone)]
struct PullRec {
    at: u64,
    params: Vec<f32>,
}

pub struct ParameterServer {
    alg: Box<dyn Algorithm>,
    schedule: LrSchedule,
    /// Per-slot pull window, oldest first.  Capacity is `pipeline + 1`: a
    /// pull beyond the cap *refreshes* the newest entry in place instead
    /// of growing the window — at the default depth 0 that is exactly the
    /// classic single-`sent` semantics (every pull overwrites; a worker
    /// may push again against its latest pull).  A push is judged against
    /// the *front* (the oldest outstanding pull — the parameters its
    /// gradient was actually computed on under a pipelined driver) and
    /// pops it, unless it is the only entry (classic re-push reuse).
    ///
    /// INVARIANT LOCKSTEP: the striped server implements the same
    /// discipline under its per-slot mutexes (`sharded.rs::SlotPulls`);
    /// any change here must be mirrored there — the
    /// `pipelined_window_matches_monolithic_exactly` test in sharded.rs
    /// pins the two against each other (sends, θ, and lag rows).
    pulls: Vec<VecDeque<PullRec>>,
    /// Recycled per-slot buffer so the steady-state pull path allocates
    /// nothing (a pop hands its buffer here; the next append takes it).
    spare: Vec<Option<Vec<f32>>>,
    /// Slot liveness (elastic membership).
    live: Vec<bool>,
    /// Master step count immediately after each slot's last applied push
    /// (`/status` table; 0 = never pushed, so a push settling as step t
    /// records t+1).  Not part of the snapshot — a resumed server
    /// restarts the table at zero.
    last_push: Vec<u64>,
    /// Pipeline depth hint (window cap − 1); see [`Master::set_pipeline_depth`].
    pipeline: usize,
    master_step: u64,
    last_eta: f32,
    momentum_correction: bool,
    pub metrics: MetricsRecorder,
}

impl ParameterServer {
    pub fn new(alg: Box<dyn Algorithm>, schedule: LrSchedule, n_workers: usize) -> Self {
        let k = alg.param_count();
        let last_eta = schedule.eta_at(0);
        ParameterServer {
            alg,
            schedule,
            pulls: vec![VecDeque::new(); n_workers],
            spare: vec![Some(vec![0.0; k]); n_workers],
            live: vec![true; n_workers],
            last_push: vec![0; n_workers],
            pipeline: 0,
            master_step: 0,
            last_eta,
            momentum_correction: true,
            metrics: MetricsRecorder::default(),
        }
    }

    pub fn with_momentum_correction(mut self, on: bool) -> Self {
        self.momentum_correction = on;
        self
    }

    /// Worker slots ever allocated (live + retired).
    pub fn n_workers(&self) -> usize {
        self.pulls.len()
    }

    /// The pull-window capacity (pipeline depth + 1), bounded by
    /// [`MAX_PULL_WINDOW`].
    fn window_cap(&self) -> usize {
        (self.pipeline + 1).min(MAX_PULL_WINDOW)
    }

    /// Outstanding pulls for `worker` (window occupancy; tests/diagnostics).
    pub fn outstanding_pulls(&self, worker: usize) -> usize {
        self.pulls.get(worker).map(VecDeque::len).unwrap_or(0)
    }

    /// Workers currently in the cluster.
    pub fn n_live(&self) -> usize {
        self.live.iter().filter(|&&l| l).count()
    }

    pub fn worker_is_live(&self, worker: usize) -> bool {
        self.live.get(worker).copied().unwrap_or(false)
    }

    /// A worker joins the cluster: claim the lowest retired slot (or
    /// append one), reset its bookkeeping, and grow the algorithm's
    /// per-worker state.  Returns the slot id.
    pub fn add_worker(&mut self) -> usize {
        let slot = claim_slot(&mut self.live);
        let k = self.alg.param_count();
        if slot == self.pulls.len() {
            self.pulls.push(VecDeque::new());
            self.spare.push(Some(vec![0.0; k]));
            self.last_push.push(0);
        } else {
            self.pulls[slot].clear();
            self.last_push[slot] = 0;
            if self.spare[slot].is_none() {
                self.spare[slot] = Some(vec![0.0; k]);
            }
        }
        let alg_slot = self.alg.add_worker();
        debug_assert!(
            alg_slot == ANY_SLOT || alg_slot == slot,
            "algorithm allocated slot {alg_slot}, server allocated {slot}"
        );
        slot
    }

    /// A worker leaves the cluster: retire its slot.  Its momentum is
    /// handled per `policy`; subsequent pushes from the slot are rejected
    /// as recoverable errors until it is reused by a joiner.  The slot's
    /// pull window is discarded — a rejoiner must pull before pushing.
    pub fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.worker_is_live(worker),
            "remove_worker: worker {worker} is not live (slots: {})",
            self.live.len()
        );
        self.live[worker] = false;
        if let Some(rec) = self.pulls[worker].pop_front() {
            self.spare[worker] = Some(rec.params);
        }
        self.pulls[worker].clear();
        self.alg.remove_worker(worker, policy);
        Ok(())
    }

    pub fn master_step(&self) -> u64 {
        self.master_step
    }

    pub fn param_count(&self) -> usize {
        self.alg.param_count()
    }

    /// Master parameters (for evaluation).
    pub fn theta(&self) -> &[f32] {
        self.alg.theta()
    }

    pub fn algorithm(&self) -> &dyn Algorithm {
        self.alg.as_ref()
    }

    pub fn algorithm_mut(&mut self) -> &mut dyn Algorithm {
        self.alg.as_mut()
    }

    /// Hyperparameters for the *current* master step.
    pub fn current_step(&self) -> Step {
        self.schedule.step_at(self.master_step)
    }

    pub fn schedule(&self) -> &LrSchedule {
        &self.schedule
    }

    /// Worker `worker` pulls parameters: what it receives depends on the
    /// algorithm (θ for ASGD-style rules, the look-ahead θ̂ for DANA/LWP).
    /// Returns a reference to the retained copy.  Pulls are master-side
    /// initiated, so a pull for a retired slot is a caller bug (panics),
    /// unlike the racy push path which errors recoverably.
    ///
    /// Window discipline: below the cap (`pipeline + 1`) the pull appends
    /// a new outstanding entry; at the cap it refreshes the newest entry
    /// in place — which at depth 0 is exactly the pre-pipeline overwrite
    /// semantics, bit for bit.
    pub fn pull(&mut self, worker: usize) -> &[f32] {
        assert!(
            self.worker_is_live(worker),
            "pull for retired/unknown worker {worker}"
        );
        let s = self.current_step();
        let t = self.master_step;
        let cap = self.window_cap();
        if self.pulls[worker].len() >= cap {
            // refresh the newest pull in place (retained-buffer reuse;
            // master_send is &self, so the disjoint field borrows coexist)
            let rec = self.pulls[worker].back_mut().expect("cap >= 1");
            rec.at = t;
            self.alg.master_send(worker, &mut rec.params, s);
        } else {
            let k = self.alg.param_count();
            let mut buf = self.spare[worker].take().unwrap_or_default();
            buf.resize(k, 0.0);
            self.alg.master_send(worker, &mut buf, s);
            self.pulls[worker].push_back(PullRec { at: t, params: buf });
        }
        &self.pulls[worker].back().expect("just written").params
    }

    /// Worker `worker` delivers its message (gradient or update vector).
    /// Applies schedule + momentum correction, records metrics, advances
    /// the master step. Returns the [`Step`] that was applied.
    ///
    /// A push from an unknown or retired worker — an in-flight update that
    /// raced a leave — is a recoverable error: nothing is applied and the
    /// caller may drop the message and continue.
    ///
    /// The push is judged against the *oldest* outstanding pull (the
    /// parameters its gradient was computed on under a pipelined driver)
    /// and consumes it, unless it is the only entry — the classic
    /// semantics where a worker may push repeatedly against its latest
    /// pull.
    pub fn push(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        self.push_inner(worker, msg, None)
    }

    /// Like [`Self::push`], applying under caller-provided, globally
    /// summed [`ApplyStats`] (phase 2 of the cluster's two-phase apply)
    /// instead of statistics computed over this server's own range.
    pub fn push_with(
        &mut self,
        worker: usize,
        msg: &[f32],
        stats: &ApplyStats,
    ) -> anyhow::Result<Step> {
        self.push_inner(worker, msg, Some(stats))
    }

    /// Phase 1 of the two-phase apply: validate the push exactly like
    /// [`Self::push`] would, then return the additive statistics partials
    /// it would produce — read-only, nothing is applied or consumed.
    /// Staging runs *before* the commit's momentum correction; that is
    /// exact because [`crate::optim::Algorithm::apply_stats`] never reads
    /// the rescaled momentum buffer (pinned by the cluster equivalence
    /// tests for YellowFin, the one rule with nontrivial stats).
    pub fn push_stats(&self, worker: usize, msg: &[f32]) -> anyhow::Result<ApplyStats> {
        anyhow::ensure!(
            worker < self.live.len(),
            "push from unknown worker {worker} (slots: {})",
            self.live.len()
        );
        anyhow::ensure!(self.live[worker], "push from retired worker {worker}");
        anyhow::ensure!(
            !self.pulls[worker].is_empty(),
            "worker {worker} pushed before ever pulling"
        );
        anyhow::ensure!(
            msg.len() == self.alg.param_count(),
            "staged push length {} != parameter count {}",
            msg.len(),
            self.alg.param_count()
        );
        let sent = &self.pulls[worker].front().expect("validated non-empty").params;
        Ok(self.alg.apply_stats(worker, msg, sent))
    }

    fn push_inner(
        &mut self,
        worker: usize,
        msg: &[f32],
        stats: Option<&ApplyStats>,
    ) -> anyhow::Result<Step> {
        anyhow::ensure!(
            worker < self.live.len(),
            "push from unknown worker {worker} (slots: {})",
            self.live.len()
        );
        anyhow::ensure!(self.live[worker], "push from retired worker {worker}");
        anyhow::ensure!(
            !self.pulls[worker].is_empty(),
            "worker {worker} pushed before ever pulling"
        );
        let s = self.schedule.step_at(self.master_step);
        if self.momentum_correction && s.eta != self.last_eta && self.last_eta > 0.0 {
            self.alg.rescale_momentum(s.eta / self.last_eta);
        }
        self.last_eta = s.eta;
        let lag =
            self.master_step - self.pulls[worker].front().expect("validated non-empty").at;

        if self.metrics.wants(self.master_step) {
            let front = self.pulls[worker].front().expect("validated non-empty");
            let sent = &front.params;
            let k = sent.len() as f64;
            let gap = crate::math::sub_norm(self.alg.theta(), sent) / k.sqrt();
            let msg_norm = crate::math::norm2_sq(msg).sqrt();
            self.metrics.record(MetricRow {
                step: self.master_step,
                worker,
                gap,
                norm_gap: if msg_norm > 0.0 { gap * k.sqrt() / msg_norm } else { 0.0 },
                lag,
                eta: s.eta,
                msg_norm,
            });
        }

        let sent = &self.pulls[worker].front().expect("validated non-empty").params;
        match stats {
            Some(st) => self.alg.master_apply_with(worker, msg, sent, s, st),
            None => self.alg.master_apply(worker, msg, sent, s),
        }
        self.metrics.note_push(lag);
        self.master_step += 1;
        self.last_push[worker] = self.master_step;
        if self.pulls[worker].len() > 1 {
            let rec = self.pulls[worker].pop_front().expect("len > 1");
            self.spare[worker] = Some(rec.params);
        }
        Ok(s)
    }
}

impl Master for ParameterServer {
    fn algo_kind(&self) -> AlgorithmKind {
        self.alg.kind()
    }

    fn workers(&self) -> usize {
        self.pulls.len()
    }

    fn live_workers(&self) -> usize {
        self.n_live()
    }

    fn is_live(&self, worker: usize) -> bool {
        self.worker_is_live(worker)
    }

    fn add_worker(&mut self) -> usize {
        ParameterServer::add_worker(self)
    }

    fn remove_worker(&mut self, worker: usize, policy: LeavePolicy) -> anyhow::Result<()> {
        ParameterServer::remove_worker(self, worker, policy)
    }

    fn steps_done(&self) -> u64 {
        self.master_step
    }

    fn param_len(&self) -> usize {
        self.alg.param_count()
    }

    fn step_now(&self) -> Step {
        self.schedule.step_at(self.master_step)
    }

    fn theta_vec(&self) -> Vec<f32> {
        self.alg.theta().to_vec()
    }

    fn pull_params(&mut self, worker: usize) -> Vec<f32> {
        self.pull(worker).to_vec()
    }

    fn pull_into(&mut self, worker: usize, out: &mut [f32]) {
        out.copy_from_slice(self.pull(worker));
    }

    fn push_update(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<Step> {
        self.push(worker, msg)
    }

    fn push_stats(&mut self, worker: usize, msg: &[f32]) -> anyhow::Result<ApplyStats> {
        ParameterServer::push_stats(self, worker, msg)
    }

    fn push_update_with(
        &mut self,
        worker: usize,
        msg: &[f32],
        stats: &ApplyStats,
    ) -> anyhow::Result<Step> {
        self.push_with(worker, msg, stats)
    }

    fn set_pipeline_depth(&mut self, depth: usize) {
        self.pipeline = depth.min(MAX_PULL_WINDOW - 1);
        self.alg.set_staleness_hint(self.pipeline);
    }

    fn make_worker_state(&self) -> WorkerState {
        self.alg.make_worker_state()
    }

    fn worker_transform(&self, ws: &mut WorkerState, grad: &mut [f32], s: Step) {
        self.alg.worker_message(ws, grad, s)
    }

    fn metrics(&self) -> &MetricsRecorder {
        &self.metrics
    }

    fn metrics_mut(&mut self) -> &mut MetricsRecorder {
        &mut self.metrics
    }

    fn slot_stats(&self, worker: usize) -> (usize, u64) {
        (
            self.outstanding_pulls(worker),
            self.last_push.get(worker).copied().unwrap_or(0),
        )
    }

    fn snapshot(&self) -> anyhow::Result<MasterSnapshot> {
        Ok(MasterSnapshot {
            kind: self.alg.kind(),
            master_step: self.master_step,
            last_eta: self.last_eta,
            theta: self.alg.theta().to_vec(),
            live: self.live.clone(),
            pulls: self
                .pulls
                .iter()
                .map(|q| q.iter().map(|r| (r.at, r.params.clone())).collect())
                .collect(),
            state: self.alg.state_dict(),
        })
    }

    fn restore(&mut self, snap: &MasterSnapshot) -> anyhow::Result<()> {
        snap.validate(self.alg.kind(), self.alg.param_count())?;
        anyhow::ensure!(
            self.master_step == 0 && self.n_live() == self.n_workers(),
            "restore target must be freshly constructed"
        );
        anyhow::ensure!(
            self.n_workers() <= snap.slots(),
            "restore target has {} slots, snapshot only {}",
            self.n_workers(),
            snap.slots()
        );
        // Replay membership so the algorithm's internal liveness (and any
        // live-count-derived scalars like LWP's τ) matches the snapshot,
        // then overwrite all state.  Retiring fresh (zero) slots is
        // side-effect-free for every rule.
        while self.pulls.len() < snap.slots() {
            ParameterServer::add_worker(self);
        }
        for (w, &alive) in snap.live.iter().enumerate() {
            if !alive {
                ParameterServer::remove_worker(self, w, LeavePolicy::Retire)?;
            }
        }
        self.alg.set_theta(&snap.theta);
        self.alg.load_state_dict(&snap.state)?;
        self.pulls = snap
            .pulls
            .iter()
            .map(|q| {
                q.iter()
                    .map(|(at, p)| PullRec { at: *at, params: p.clone() })
                    .collect()
            })
            .collect();
        self.master_step = snap.master_step;
        self.last_eta = snap.last_eta;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{make_algorithm, AlgorithmKind, ScheduleConfig};

    fn server(kind: AlgorithmKind, n: usize, k: usize) -> ParameterServer {
        let theta0 = vec![1.0f32; k];
        let schedule = LrSchedule::new(ScheduleConfig {
            warmup_epochs: 0.0,
            decay_epochs: vec![],
            steps_per_epoch: 10,
            n_workers: n,
            ..ScheduleConfig::default()
        });
        ParameterServer::new(make_algorithm(kind, &theta0, n), schedule, n)
    }

    #[test]
    fn pull_push_cycle_advances_master() {
        let mut ps = server(AlgorithmKind::Asgd, 2, 4);
        let p = ps.pull(0).to_vec();
        assert_eq!(p, vec![1.0; 4]);
        ps.push(0, &[1.0; 4]).unwrap();
        assert_eq!(ps.master_step(), 1);
        assert!(ps.theta()[0] < 1.0);
    }

    #[test]
    fn push_without_pull_is_recoverable_error() {
        let mut ps = server(AlgorithmKind::Asgd, 2, 4);
        let err = ps.push(1, &[0.0; 4]).unwrap_err();
        assert!(err.to_string().contains("pushed before ever pulling"));
        assert_eq!(ps.master_step(), 0, "failed push must not advance");
        // the server is still usable afterwards
        ps.pull(1);
        ps.push(1, &[0.0; 4]).unwrap();
    }

    #[test]
    fn push_from_retired_worker_is_recoverable_error() {
        let mut ps = server(AlgorithmKind::DanaZero, 3, 4);
        ps.pull(1);
        ps.remove_worker(1, LeavePolicy::Retire).unwrap();
        let err = ps.push(1, &[0.1; 4]).unwrap_err();
        assert!(err.to_string().contains("retired worker 1"), "{err}");
        assert!(ps.push(7, &[0.1; 4]).is_err(), "unknown slot rejected");
        assert_eq!(ps.master_step(), 0);
        // double-remove errors too
        assert!(ps.remove_worker(1, LeavePolicy::Retire).is_err());
    }

    #[test]
    fn membership_reuses_slots_and_counts_live() {
        let mut ps = server(AlgorithmKind::MultiAsgd, 3, 4);
        assert_eq!((ps.n_workers(), ps.n_live()), (3, 3));
        ps.remove_worker(0, LeavePolicy::Retire).unwrap();
        assert_eq!((ps.n_workers(), ps.n_live()), (3, 2));
        assert_eq!(ps.add_worker(), 0, "lowest retired slot reused");
        assert_eq!(ps.add_worker(), 3, "then append");
        assert_eq!((ps.n_workers(), ps.n_live()), (4, 4));
        // a rejoined slot must re-pull before pushing
        let err = ps.push(0, &[0.0; 4]).unwrap_err();
        assert!(err.to_string().contains("before ever pulling"));
        ps.pull(0);
        ps.push(0, &[1.0; 4]).unwrap();
    }

    #[test]
    fn lag_counts_intervening_updates() {
        let mut ps = server(AlgorithmKind::Asgd, 3, 2);
        ps.metrics.set_every(1);
        ps.pull(0);
        ps.pull(1);
        ps.pull(2);
        ps.push(1, &[0.1; 2]).unwrap(); // lag 0
        ps.push(2, &[0.1; 2]).unwrap(); // lag 1
        ps.push(0, &[0.1; 2]).unwrap(); // lag 2
        let lags: Vec<u64> = ps.metrics.rows().iter().map(|r| r.lag).collect();
        assert_eq!(lags, vec![0, 1, 2]);
    }

    #[test]
    fn gap_is_zero_without_intervening_updates() {
        let mut ps = server(AlgorithmKind::Asgd, 1, 8);
        ps.metrics.set_every(1);
        ps.pull(0);
        ps.push(0, &[0.5; 8]).unwrap();
        assert_eq!(ps.metrics.rows()[0].gap, 0.0);
        // second round: worker pulled fresh params, still no interleaving
        ps.pull(0);
        ps.push(0, &[0.5; 8]).unwrap();
        assert_eq!(ps.metrics.rows()[1].gap, 0.0);
    }

    #[test]
    fn gap_grows_with_stale_pull() {
        let mut ps = server(AlgorithmKind::Asgd, 2, 8);
        ps.metrics.set_every(1);
        ps.pull(0);
        ps.pull(1);
        ps.push(1, &[1.0; 8]).unwrap();
        ps.push(0, &[1.0; 8]).unwrap(); // worker 0's params now one update stale
        let rows = ps.metrics.rows();
        assert_eq!(rows[0].gap, 0.0);
        assert!(rows[1].gap > 0.0);
    }

    #[test]
    fn master_trait_unifies_both_layouts() {
        let theta0 = vec![1.0f32; 8];
        let sched = || {
            LrSchedule::new(ScheduleConfig {
                warmup_epochs: 0.0,
                decay_epochs: vec![],
                steps_per_epoch: 10,
                n_workers: 2,
                ..ScheduleConfig::default()
            })
        };
        for shards in [1usize, 4] {
            let mut m = make_master(AlgorithmKind::DanaZero, &theta0, sched(), 2, shards, 2);
            assert_eq!(m.param_len(), 8);
            assert_eq!(m.workers(), 2);
            assert_eq!(m.algo_kind(), AlgorithmKind::DanaZero);
            let p = m.pull_params(0);
            assert_eq!(p, theta0);
            m.push_update(0, &[1.0; 8]).unwrap();
            assert_eq!(m.steps_done(), 1);
            assert!(m.theta_vec()[0] < 1.0);
            // membership through the trait: join, leave, recoverable push
            assert_eq!(m.live_workers(), 2);
            let w = m.add_worker();
            assert_eq!(w, 2);
            m.pull_params(w);
            m.push_update(w, &[0.5; 8]).unwrap();
            m.remove_worker(w, LeavePolicy::Fold).unwrap();
            assert!(!m.is_live(w));
            assert!(m.push_update(w, &[0.5; 8]).is_err());
            assert_eq!(m.live_workers(), 2);
        }
    }

    #[test]
    fn sharded_layouts_match_monolithic_through_the_trait() {
        let theta0: Vec<f32> = (0..11).map(|i| (i as f32 * 0.7).sin()).collect();
        let sched = || {
            LrSchedule::new(ScheduleConfig {
                warmup_epochs: 0.0,
                decay_epochs: vec![],
                steps_per_epoch: 10,
                n_workers: 2,
                ..ScheduleConfig::default()
            })
        };
        let mut mono = make_master(AlgorithmKind::DanaDc, &theta0, sched(), 2, 1, 1);
        let mut shrd = make_master(AlgorithmKind::DanaDc, &theta0, sched(), 2, 3, 2);
        for step in 0..30 {
            let w = step % 2;
            let a = mono.pull_params(w);
            let b = shrd.pull_params(w);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-6, "step {step}: {x} vs {y}");
            }
            let g: Vec<f32> = a.iter().map(|&x| 0.1 * x + 0.01).collect();
            mono.push_update(w, &g).unwrap();
            shrd.push_update(w, &g).unwrap();
        }
    }

    #[test]
    fn snapshot_restore_round_trips_across_layouts() {
        // Drive a churned run, snapshot, restore into BOTH layouts (and a
        // different shard count), and require identical continuations.
        let k = 19;
        let theta0: Vec<f32> = (0..k).map(|i| (i as f32 * 0.31).cos()).collect();
        let sched = || {
            LrSchedule::new(ScheduleConfig {
                warmup_epochs: 0.0,
                decay_epochs: vec![],
                steps_per_epoch: 10,
                n_workers: 3,
                ..ScheduleConfig::default()
            })
        };
        // Build a source at `src_shards`, drive 20 churned steps, and
        // retire a worker — rebuilt fresh for every restore target so the
        // continuation comparison starts from the snapshot both sides.
        let build_src = |kind: AlgorithmKind, src_shards: usize| -> Box<dyn Master> {
            let mut src = make_master(kind, &theta0, sched(), 3, src_shards, 2);
            for i in 0..20 {
                let w = i % 3;
                let p = src.pull_params(w);
                let g: Vec<f32> = p.iter().map(|&x| 0.1 * x + 0.02).collect();
                src.push_update(w, &g).unwrap();
            }
            src.remove_worker(1, LeavePolicy::Retire).unwrap();
            src
        };
        // Elementwise rules are bit-for-bit across shard counts; YellowFin
        // restores exactly only into the same layout (its tuner reduces
        // f64 sums in shard order; cross-layout is only ~1e-5 close — the
        // property suite pins that tolerance).
        for kind in [AlgorithmKind::DanaDc, AlgorithmKind::Easgd, AlgorithmKind::YellowFin] {
            for src_shards in [1usize, 3] {
                let dst_shard_choices: Vec<usize> = if kind == AlgorithmKind::YellowFin {
                    vec![src_shards]
                } else {
                    vec![1, 2, 4, src_shards]
                };
                for dst_shards in dst_shard_choices {
                    let mut src = build_src(kind, src_shards);
                    let snap = src.snapshot().unwrap();
                    assert_eq!(snap.slots(), 3);
                    assert_eq!(snap.master_step, 20);
                    let mut dst = make_master(kind, &theta0, sched(), 0, dst_shards, 2);
                    dst.restore(&snap).unwrap();
                    assert_eq!(dst.steps_done(), 20, "{kind} S={dst_shards}");
                    assert_eq!(dst.theta_vec(), src.theta_vec(), "{kind} S={dst_shards}");
                    assert_eq!(dst.live_workers(), 2);
                    assert!(!dst.is_live(1));
                    // continuation must match the source exactly
                    for i in 0..10 {
                        let w = [0, 2][i % 2];
                        let a = src.pull_params(w);
                        let b = dst.pull_params(w);
                        assert_eq!(a, b, "{kind} S={dst_shards}: send diverged");
                        let g: Vec<f32> = a.iter().map(|&x| 0.1 * x - 0.01).collect();
                        src.push_update(w, &g).unwrap();
                        dst.push_update(w, &g).unwrap();
                    }
                    assert_eq!(dst.theta_vec(), src.theta_vec(), "{kind} S={dst_shards}");
                }
            }
        }
    }

    #[test]
    fn restore_fails_closed_on_mismatch() {
        let theta0 = vec![1.0f32; 8];
        let sched = || {
            LrSchedule::new(ScheduleConfig {
                warmup_epochs: 0.0,
                decay_epochs: vec![],
                steps_per_epoch: 10,
                n_workers: 2,
                ..ScheduleConfig::default()
            })
        };
        let src = make_master(AlgorithmKind::DanaZero, &theta0, sched(), 2, 1, 1);
        let snap = src.snapshot().unwrap();
        // wrong algorithm
        let mut dst = make_master(AlgorithmKind::Asgd, &theta0, sched(), 0, 1, 1);
        assert!(dst.restore(&snap).is_err());
        // wrong parameter count
        let mut dst = make_master(AlgorithmKind::DanaZero, &[0.0; 4], sched(), 0, 1, 1);
        assert!(dst.restore(&snap).is_err());
        // non-fresh target
        let mut dst = make_master(AlgorithmKind::DanaZero, &theta0, sched(), 2, 1, 1);
        dst.pull_params(0);
        dst.push_update(0, &[0.1; 8]).unwrap();
        assert!(dst.restore(&snap).is_err());
        // too many pre-allocated slots
        let mut dst = make_master(AlgorithmKind::DanaZero, &theta0, sched(), 5, 1, 1);
        assert!(dst.restore(&snap).is_err());
    }

    #[test]
    fn depth_zero_window_keeps_classic_overwrite_semantics() {
        // repeated pulls overwrite the single window entry, and a worker
        // may push repeatedly against its latest pull — exactly the
        // pre-pipeline behavior.
        let mut ps = server(AlgorithmKind::Asgd, 2, 4);
        ps.metrics.set_every(1);
        ps.pull(0);
        ps.pull(0);
        assert_eq!(ps.outstanding_pulls(0), 1, "depth 0 window never grows");
        ps.push(0, &[0.1; 4]).unwrap();
        ps.push(0, &[0.1; 4]).unwrap();
        let lags: Vec<u64> = ps.metrics.rows().iter().map(|r| r.lag).collect();
        assert_eq!(lags, vec![0, 1], "re-push reuses the latest pull's step");
    }

    #[test]
    fn pipeline_window_judges_push_against_oldest_pull() {
        let mut ps = server(AlgorithmKind::Asgd, 1, 2);
        ps.set_pipeline_depth(2);
        ps.metrics.set_every(1);
        for _ in 0..3 {
            ps.pull(0); // prime the depth-2 window (cap 3)
        }
        assert_eq!(ps.outstanding_pulls(0), 3);
        ps.pull(0); // beyond the cap: refreshes the newest, window stays 3
        assert_eq!(ps.outstanding_pulls(0), 3);
        for _ in 0..5 {
            ps.push(0, &[0.1; 2]).unwrap();
            ps.pull(0);
        }
        let lags: Vec<u64> = ps.metrics.rows().iter().map(|r| r.lag).collect();
        // primed pulls all at step 0 → lags ramp 0,1,2 then settle at the
        // pipeline depth: the +D staleness shift, exactly.
        assert_eq!(lags, vec![0, 1, 2, 2, 2]);
    }

    #[test]
    fn pipelined_dc_compensates_against_the_pull_it_was_computed_on() {
        // DC-ASGD's Taylor term uses θ_sent: under a depth-1 window the
        // second push must be compensated toward the SECOND pull — not
        // the most recent one.
        let theta0 = vec![1.0f32; 8];
        let mut ps = server(AlgorithmKind::DcAsgd, 2, 8);
        ps.set_pipeline_depth(1);
        let mut reference = make_algorithm(AlgorithmKind::DcAsgd, &theta0, 2);
        let s = ps.current_step(); // flat schedule: constant Step
        let p1 = ps.pull(0).to_vec();
        // another worker's push lands between worker 0's windowed pulls
        let q1 = ps.pull(1).to_vec();
        ps.push(1, &[0.5; 8]).unwrap();
        reference.master_apply(1, &[0.5; 8], &q1, s);
        let p2 = ps.pull(0).to_vec();
        assert_ne!(p1, p2, "test premise: the windowed pulls must differ");
        ps.push(0, &[0.3; 8]).unwrap();
        reference.master_apply(0, &[0.3; 8], &p1, s);
        assert_eq!(ps.theta(), reference.theta(), "first push judged against p1");
        ps.pull(0);
        ps.push(0, &[0.2; 8]).unwrap();
        reference.master_apply(0, &[0.2; 8], &p2, s);
        assert_eq!(ps.theta(), reference.theta(), "second push judged against p2");
    }

    #[test]
    fn pipelined_window_round_trips_through_snapshot() {
        let mut ps = server(AlgorithmKind::DanaZero, 2, 4);
        ps.set_pipeline_depth(1);
        ps.pull(0);
        ps.pull(0);
        ps.pull(1);
        ps.push(0, &[0.2; 4]).unwrap();
        let snap = ps.snapshot().unwrap();
        assert_eq!(snap.pulls[0].len(), 1, "push consumed the oldest entry");
        assert_eq!(snap.pulls[1].len(), 1);
        let mut dst = server(AlgorithmKind::DanaZero, 2, 4);
        dst.set_pipeline_depth(1);
        dst.restore(&snap).unwrap();
        // continuation equality: same pushes against the restored window
        ps.push(0, &[0.1; 4]).unwrap();
        dst.push(0, &[0.1; 4]).unwrap();
        ps.push(1, &[0.4; 4]).unwrap();
        dst.push(1, &[0.4; 4]).unwrap();
        assert_eq!(ps.theta(), dst.theta());
        assert_eq!(ps.snapshot().unwrap(), dst.snapshot().unwrap());
    }

    #[test]
    fn push_feeds_hub_and_slot_stats() {
        let mut ps = server(AlgorithmKind::Asgd, 2, 4);
        ps.pull(0);
        ps.pull(1);
        ps.push(0, &[0.1; 4]).unwrap(); // lag 0, settles as step 0
        ps.push(1, &[0.1; 4]).unwrap(); // lag 1, settles as step 1
        let hub = ps.metrics.hub_handle();
        assert_eq!(hub.pushes_total(), 2, "every push counted, sampling off");
        assert_eq!(hub.lag_histogram().count, 2);
        assert_eq!(hub.lag_histogram().sum, 1.0, "lags 0 + 1");
        assert_eq!(Master::slot_stats(&ps, 0), (1, 1));
        assert_eq!(Master::slot_stats(&ps, 1), (1, 2));
        assert_eq!(Master::slot_stats(&ps, 9), (0, 0), "unknown slot reads zero");
    }

    #[test]
    fn serving_scrape_accessors_track_membership() {
        let theta0 = vec![1.0f32; 8];
        let sched = || {
            LrSchedule::new(ScheduleConfig {
                warmup_epochs: 0.0,
                decay_epochs: vec![],
                steps_per_epoch: 10,
                n_workers: 2,
                ..ScheduleConfig::default()
            })
        };
        for striped in [false, true] {
            let sm = make_serving_master(
                AlgorithmKind::DanaZero,
                &theta0,
                sched(),
                2,
                2,
                1,
                striped,
            );
            assert_eq!(sm.worker_counts(), (2, 2), "striped={striped}");
            let w = sm.join();
            assert_eq!(sm.worker_counts(), (3, 3), "striped={striped}");
            sm.pull(w).unwrap();
            sm.push(w, &[0.1; 8]).unwrap();
            assert_eq!(sm.metrics_hub().pushes_total(), 1, "striped={striped}");
            let table = sm.slot_table();
            assert_eq!(table.len(), 3, "striped={striped}");
            assert!(table[w].live && table[w].window == 1 && table[w].last_push == 1);
            sm.leave(w, LeavePolicy::Retire).unwrap();
            assert_eq!(sm.worker_counts(), (2, 3), "striped={striped}");
            assert!(!sm.slot_table()[w].live, "striped={striped}");
            if striped {
                let gates = sm.shard_gates();
                assert_eq!(gates.len(), 2, "one gate pair per shard");
                assert!(gates.iter().all(|&(pos, backlog)| pos == 1 && backlog == 0));
            } else {
                assert!(sm.shard_gates().is_empty(), "no gates on the locked path");
            }
        }
    }

    #[test]
    fn dana_send_differs_from_theta_once_momentum_exists() {
        let mut ps = server(AlgorithmKind::DanaZero, 2, 4);
        ps.pull(0);
        ps.push(0, &[1.0; 4]).unwrap();
        let theta = ps.theta().to_vec();
        let hat = ps.pull(1).to_vec();
        assert_ne!(theta, hat, "look-ahead must differ once v != 0");
    }

    #[test]
    fn momentum_correction_fires_on_decay() {
        // schedule decays at epoch 1 (step 10); NAG momentum must rescale.
        let theta0 = vec![0.0f32; 2];
        let schedule = LrSchedule::new(ScheduleConfig {
            warmup_epochs: 0.0,
            decay_epochs: vec![1.0],
            decay_factor: 0.1,
            steps_per_epoch: 10,
            n_workers: 1,
            ..ScheduleConfig::default()
        });
        let mut ps = ParameterServer::new(
            make_algorithm(AlgorithmKind::NagAsgd, &theta0, 1),
            schedule,
            1,
        );
        for _ in 0..12 {
            ps.pull(0);
            ps.push(0, &[1.0, 1.0]).unwrap();
        }
        // if we got here without NaN and theta is finite, correction applied;
        // detailed numeric equivalence is covered in optimizer tests.
        assert!(ps.theta().iter().all(|x| x.is_finite()));
    }
}

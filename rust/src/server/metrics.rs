//! Gap / lag / gradient-norm instrumentation (paper Section 3, Fig 2 & 11).
//!
//! Two tiers share this module:
//!
//! * [`MetricsRecorder`] — the sampled row log the experiment harness
//!   reads back (`rows`/`take_rows`); unchanged semantics, every
//!   `every`-th master step keeps a full [`MetricRow`].
//! * [`MetricsHub`] — lock-free counters and fixed-bucket histograms for
//!   the daemon's `/metrics` endpoint.  Everything in the hub is plain
//!   atomics: the scrape path reads it without ever taking a lock the
//!   push hot path wants (the recorder's row mutex included), so a slow
//!   or stuck scraper cannot stall a single push.  `note_push` is O(1)
//!   and fed on *every* apply; the gap histogram is fed from the sampled
//!   `record` calls only, because the gap itself costs an O(k) norm pass
//!   the server only pays on sampled steps.

use crate::util::sync;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One sampled master-apply event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricRow {
    pub step: u64,
    pub worker: usize,
    /// G(Δ) = ‖θ_now − θ_sent‖₂ / √k  — the paper's gap.
    pub gap: f64,
    /// Normalized gap G*(Δ) = ‖Δ‖ / ‖msg‖ (Appendix B.3).
    pub norm_gap: f64,
    /// τ — master updates since this worker's pull.
    pub lag: u64,
    pub eta: f32,
    /// ‖msg‖₂ (gradient norm for gradient-sending algorithms).
    pub msg_norm: f64,
}

/// Gap bucket bounds: log decades spanning collapsed (DANA, ~1e-5) to
/// diverging (fixed-momentum ASGD at large N) gaps.
pub const GAP_BOUNDS: &[f64] =
    &[1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0];

/// Lag bucket bounds: powers of two out to well past any sane
/// N·(D+1) in-flight multiplicity.
pub const LAG_BOUNDS: &[f64] =
    &[0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0];

/// Fixed-bucket histogram over atomics: `observe` is wait-free modulo
/// the f64-sum CAS loop, `snapshot` is a plain load per bucket.  Bucket
/// `i` counts observations `<= bounds[i]`; one extra bucket counts the
/// overflow (+inf), Prometheus-style.
#[derive(Debug)]
pub struct AtomicHistogram {
    bounds: &'static [f64],
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum of observed values, stored as f64 bits (CAS-accumulated).
    sum_bits: AtomicU64,
}

impl AtomicHistogram {
    pub fn new(bounds: &'static [f64]) -> AtomicHistogram {
        AtomicHistogram {
            bounds,
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    pub fn observe(&self, v: f64) {
        let i = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                new,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Consistent-enough copy for a scrape (individual loads are atomic;
    /// a push landing mid-snapshot skews one bucket by one, which a
    /// monitoring scrape tolerates by design).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            bounds: self.bounds,
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
        }
    }
}

/// Point-in-time copy of an [`AtomicHistogram`].
#[derive(Debug, Clone)]
pub struct HistogramSnapshot {
    pub bounds: &'static [f64],
    /// Per-bucket (non-cumulative) counts; `buckets[bounds.len()]` is
    /// the +inf overflow bucket.
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl HistogramSnapshot {
    /// Approximate quantile by linear interpolation inside the bucket
    /// holding the target rank.  The +inf bucket clamps to the last
    /// finite bound (an upper-bound estimate is still monotone in q).
    /// Returns 0.0 when nothing was observed.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let next = cum + n;
            if (next as f64) >= target {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    // +inf bucket: clamp to the largest finite bound
                    return self.bounds[self.bounds.len() - 1];
                };
                let frac = ((target - cum as f64) / n as f64).clamp(0.0, 1.0);
                return lo + (hi - lo) * frac;
            }
            cum = next;
        }
        self.bounds[self.bounds.len() - 1]
    }
}

/// Lock-free metric sources for the `/metrics` scrape path: a push
/// counter (every apply), a lag histogram (every apply, the lag is
/// already computed O(1) on the push path) and a gap histogram (sampled
/// applies only — the gap costs an O(k) norm pass).  Shared by `Arc` so
/// the HTTP listener holds its own handle and never touches master
/// state.
#[derive(Debug)]
pub struct MetricsHub {
    pushes: AtomicU64,
    /// Wire bytes sent / received by the transport server (frame bytes,
    /// length prefixes included) — the compression smoke's ground truth.
    bytes_tx: AtomicU64,
    bytes_rx: AtomicU64,
    gap: AtomicHistogram,
    lag: AtomicHistogram,
}

impl Default for MetricsHub {
    fn default() -> MetricsHub {
        MetricsHub {
            pushes: AtomicU64::new(0),
            bytes_tx: AtomicU64::new(0),
            bytes_rx: AtomicU64::new(0),
            gap: AtomicHistogram::new(GAP_BOUNDS),
            lag: AtomicHistogram::new(LAG_BOUNDS),
        }
    }
}

impl MetricsHub {
    /// Count one applied push and record its lag.  O(1), atomics only.
    pub fn note_push(&self, lag: u64) {
        self.pushes.fetch_add(1, Ordering::Relaxed);
        self.lag.observe(lag as f64);
    }

    /// Record one sampled gap observation.
    pub fn note_gap(&self, gap: f64) {
        self.gap.observe(gap);
    }

    /// Count `n` wire bytes written to a client.
    pub fn note_tx(&self, n: usize) {
        self.bytes_tx.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Count `n` wire bytes read from a client.
    pub fn note_rx(&self, n: usize) {
        self.bytes_rx.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn bytes_tx_total(&self) -> u64 {
        self.bytes_tx.load(Ordering::Relaxed)
    }

    pub fn bytes_rx_total(&self) -> u64 {
        self.bytes_rx.load(Ordering::Relaxed)
    }

    pub fn pushes_total(&self) -> u64 {
        self.pushes.load(Ordering::Relaxed)
    }

    pub fn gap_histogram(&self) -> HistogramSnapshot {
        self.gap.snapshot()
    }

    pub fn lag_histogram(&self) -> HistogramSnapshot {
        self.lag.snapshot()
    }
}

/// Sampling recorder: keeps every `every`-th master step (0 = disabled).
///
/// Recording is `&self` (rows behind a mutex) so the striped server's
/// concurrent pushes can record without holding any master-state lock;
/// configuration (`set_every`) stays `&mut self` — it happens before the
/// server is shared.  Under concurrent pushes rows land in completion
/// order; serial drivers (the equivalence suites) observe step order
/// exactly as before.
///
/// The recorder also owns a [`MetricsHub`] handle: `record` feeds the
/// hub's gap histogram and `note_push` its push counter + lag histogram,
/// so both server backends export scrape data through one tap.
#[derive(Debug, Default)]
pub struct MetricsRecorder {
    every: u64,
    rows: Mutex<Vec<MetricRow>>,
    hub: Arc<MetricsHub>,
}

impl MetricsRecorder {
    pub fn set_every(&mut self, every: u64) {
        self.every = every;
    }

    pub fn wants(&self, step: u64) -> bool {
        self.every > 0 && step % self.every == 0
    }

    /// Clone the lock-free hub handle for a scrape endpoint.
    pub fn hub_handle(&self) -> Arc<MetricsHub> {
        Arc::clone(&self.hub)
    }

    /// Forwarded to [`MetricsHub::note_push`] — call once per applied
    /// push, whether or not the step is sampled.
    pub fn note_push(&self, lag: u64) {
        self.hub.note_push(lag);
    }

    pub fn record(&self, row: MetricRow) {
        self.hub.note_gap(row.gap);
        sync::lock(&self.rows).push(row);
    }

    /// Snapshot of the recorded rows (sampled sparsely; the copy is cheap
    /// next to the O(k) traffic it measures).
    pub fn rows(&self) -> Vec<MetricRow> {
        sync::lock(&self.rows).clone()
    }

    pub fn take_rows(&mut self) -> Vec<MetricRow> {
        std::mem::take(&mut *sync::lock(&self.rows))
    }

    /// Mean gap over all recorded rows (Fig 2b summary statistic).
    pub fn mean_gap(&self) -> f64 {
        let rows = sync::lock(&self.rows);
        if rows.is_empty() {
            return 0.0;
        }
        rows.iter().map(|r| r.gap).sum::<f64>() / rows.len() as f64
    }

    /// Mean lag over all recorded rows.
    pub fn mean_lag(&self) -> f64 {
        let rows = sync::lock(&self.rows);
        if rows.is_empty() {
            return 0.0;
        }
        rows.iter().map(|r| r.lag as f64).sum::<f64>() / rows.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(step: u64, gap: f64, lag: u64) -> MetricRow {
        MetricRow { step, worker: 0, gap, norm_gap: 0.0, lag, eta: 0.1, msg_norm: 1.0 }
    }

    #[test]
    fn disabled_by_default() {
        let m = MetricsRecorder::default();
        assert!(!m.wants(0));
    }

    #[test]
    fn sampling_cadence() {
        let mut m = MetricsRecorder::default();
        m.set_every(10);
        assert!(m.wants(0) && m.wants(20) && !m.wants(5));
    }

    #[test]
    fn aggregates() {
        let mut m = MetricsRecorder::default();
        m.set_every(1);
        m.record(row(0, 1.0, 2));
        m.record(row(1, 3.0, 4));
        assert_eq!(m.mean_gap(), 2.0);
        assert_eq!(m.mean_lag(), 3.0);
    }

    #[test]
    fn concurrent_recording_keeps_every_row() {
        let mut m = MetricsRecorder::default();
        m.set_every(1);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let m = &m;
                s.spawn(move || {
                    for i in 0..50 {
                        m.record(row(t * 100 + i, 0.0, 0));
                    }
                });
            }
        });
        assert_eq!(m.rows().len(), 200);
        assert_eq!(m.take_rows().len(), 200);
        assert!(m.rows().is_empty());
    }

    #[test]
    fn histogram_buckets_and_sum_are_exact() {
        let h = AtomicHistogram::new(LAG_BOUNDS);
        for lag in [0u64, 0, 1, 2, 3, 5, 2000] {
            h.observe(lag as f64);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 7);
        assert_eq!(s.sum, 2011.0);
        assert_eq!(s.buckets[0], 2, "two zeros in the <=0 bucket");
        assert_eq!(s.buckets[1], 1, "one in (0,1]");
        assert_eq!(s.buckets[2], 1, "one in (1,2]");
        assert_eq!(s.buckets[3], 1, "3 lands in (2,4]");
        assert_eq!(s.buckets[4], 1, "5 lands in (4,8]");
        assert_eq!(*s.buckets.last().unwrap(), 1, "2000 overflows to +inf");
    }

    #[test]
    fn quantile_interpolates_and_clamps() {
        let h = AtomicHistogram::new(LAG_BOUNDS);
        assert_eq!(h.snapshot().quantile(0.5), 0.0, "empty histogram reads 0");
        for _ in 0..100 {
            h.observe(1.0);
        }
        let s = h.snapshot();
        let q50 = s.quantile(0.5);
        assert!((0.0..=1.0).contains(&q50), "median of all-1s in (0,1]: {q50}");
        assert!(s.quantile(1.0) <= 1.0);
        // overflow observations clamp to the last finite bound
        let o = AtomicHistogram::new(LAG_BOUNDS);
        o.observe(1e9);
        assert_eq!(o.snapshot().quantile(0.99), *LAG_BOUNDS.last().unwrap());
    }

    #[test]
    fn hub_counts_every_push_without_locks() {
        let hub = MetricsHub::default();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let hub = &hub;
                s.spawn(move || {
                    for lag in 0..50u64 {
                        hub.note_push(lag);
                    }
                });
            }
        });
        assert_eq!(hub.pushes_total(), 200);
        let lags = hub.lag_histogram();
        assert_eq!(lags.count, 200);
        assert_eq!(lags.sum, 4.0 * (0..50).sum::<u64>() as f64);
    }

    #[test]
    fn hub_byte_counters_accumulate() {
        let hub = MetricsHub::default();
        assert_eq!((hub.bytes_tx_total(), hub.bytes_rx_total()), (0, 0));
        hub.note_tx(100);
        hub.note_tx(28);
        hub.note_rx(7);
        assert_eq!(hub.bytes_tx_total(), 128);
        assert_eq!(hub.bytes_rx_total(), 7);
    }

    #[test]
    fn recorder_feeds_hub_gap_on_record_and_lag_on_note_push() {
        let mut m = MetricsRecorder::default();
        m.set_every(1);
        m.record(row(0, 0.5, 3));
        m.note_push(3);
        let hub = m.hub_handle();
        assert_eq!(hub.gap_histogram().count, 1);
        assert_eq!(hub.pushes_total(), 1);
        assert_eq!(hub.lag_histogram().sum, 3.0);
    }
}

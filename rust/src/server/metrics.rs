//! Gap / lag / gradient-norm instrumentation (paper Section 3, Fig 2 & 11).

use crate::util::sync;
use std::sync::Mutex;

/// One sampled master-apply event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricRow {
    pub step: u64,
    pub worker: usize,
    /// G(Δ) = ‖θ_now − θ_sent‖₂ / √k  — the paper's gap.
    pub gap: f64,
    /// Normalized gap G*(Δ) = ‖Δ‖ / ‖msg‖ (Appendix B.3).
    pub norm_gap: f64,
    /// τ — master updates since this worker's pull.
    pub lag: u64,
    pub eta: f32,
    /// ‖msg‖₂ (gradient norm for gradient-sending algorithms).
    pub msg_norm: f64,
}

/// Sampling recorder: keeps every `every`-th master step (0 = disabled).
///
/// Recording is `&self` (rows behind a mutex) so the striped server's
/// concurrent pushes can record without holding any master-state lock;
/// configuration (`set_every`) stays `&mut self` — it happens before the
/// server is shared.  Under concurrent pushes rows land in completion
/// order; serial drivers (the equivalence suites) observe step order
/// exactly as before.
#[derive(Debug, Default)]
pub struct MetricsRecorder {
    every: u64,
    rows: Mutex<Vec<MetricRow>>,
}

impl MetricsRecorder {
    pub fn set_every(&mut self, every: u64) {
        self.every = every;
    }

    pub fn wants(&self, step: u64) -> bool {
        self.every > 0 && step % self.every == 0
    }

    pub fn record(&self, row: MetricRow) {
        sync::lock(&self.rows).push(row);
    }

    /// Snapshot of the recorded rows (sampled sparsely; the copy is cheap
    /// next to the O(k) traffic it measures).
    pub fn rows(&self) -> Vec<MetricRow> {
        sync::lock(&self.rows).clone()
    }

    pub fn take_rows(&mut self) -> Vec<MetricRow> {
        std::mem::take(&mut *sync::lock(&self.rows))
    }

    /// Mean gap over all recorded rows (Fig 2b summary statistic).
    pub fn mean_gap(&self) -> f64 {
        let rows = sync::lock(&self.rows);
        if rows.is_empty() {
            return 0.0;
        }
        rows.iter().map(|r| r.gap).sum::<f64>() / rows.len() as f64
    }

    /// Mean lag over all recorded rows.
    pub fn mean_lag(&self) -> f64 {
        let rows = sync::lock(&self.rows);
        if rows.is_empty() {
            return 0.0;
        }
        rows.iter().map(|r| r.lag as f64).sum::<f64>() / rows.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(step: u64, gap: f64, lag: u64) -> MetricRow {
        MetricRow { step, worker: 0, gap, norm_gap: 0.0, lag, eta: 0.1, msg_norm: 1.0 }
    }

    #[test]
    fn disabled_by_default() {
        let m = MetricsRecorder::default();
        assert!(!m.wants(0));
    }

    #[test]
    fn sampling_cadence() {
        let mut m = MetricsRecorder::default();
        m.set_every(10);
        assert!(m.wants(0) && m.wants(20) && !m.wants(5));
    }

    #[test]
    fn aggregates() {
        let mut m = MetricsRecorder::default();
        m.set_every(1);
        m.record(row(0, 1.0, 2));
        m.record(row(1, 3.0, 4));
        assert_eq!(m.mean_gap(), 2.0);
        assert_eq!(m.mean_lag(), 3.0);
    }

    #[test]
    fn concurrent_recording_keeps_every_row() {
        let mut m = MetricsRecorder::default();
        m.set_every(1);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let m = &m;
                s.spawn(move || {
                    for i in 0..50 {
                        m.record(row(t * 100 + i, 0.0, 0));
                    }
                });
            }
        });
        assert_eq!(m.rows().len(), 200);
        assert_eq!(m.take_rows().len(), 200);
        assert!(m.rows().is_empty());
    }
}

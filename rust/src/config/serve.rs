//! [`ServeSpec`] — everything one `dana serve` process needs, as data.
//!
//! `dana serve` grew ~20 flags across PRs 5–8; the cluster manifest
//! (DESIGN.md §14) expresses the same settings declaratively.  This
//! struct is the normalization point: the flag parser fills one, and
//! [`ServeSpec::from_manifest`] fills an identical one from a named
//! `servers[]` entry — so a manifest-launched server and a hand-flagged
//! server are the same code path from here down, and golden tests can
//! compare the two spellings with `==`.

use crate::cluster::manifest::ClusterManifest;
use crate::config::Workload;
use crate::math::KernelChoice;
use crate::net::{EncodingSet, RetentionPolicy};
use crate::optim::{AlgorithmKind, LeavePolicy};
use std::ops::Range;
use std::path::{Path, PathBuf};

/// Standby role: watch `primary` and take its range over on failure
/// (`--standby-of`, or a manifest `standbys[]` entry).
#[derive(Debug, Clone, PartialEq)]
pub struct StandbyOf {
    /// The watched primary's serving address (scheme optional).
    pub primary: String,
    pub poll_ms: u64,
    pub miss_budget: u32,
}

/// One parameter-server process, fully specified.  See the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeSpec {
    pub listen: String,
    pub algorithm: AlgorithmKind,
    pub workload: Workload,
    /// `Some(k)` = synthetic quadratic of dimension k (artifact-free).
    pub synthetic_k: Option<usize>,
    /// Schedule worker count (the server owns the LR schedule).
    pub workers: usize,
    pub epochs: f64,
    pub seed: u64,
    pub eta: Option<f32>,
    pub gamma: Option<f32>,
    /// Global shard count (local count unless `shard_range` narrows it).
    pub shards: usize,
    /// Hosted slice `[A, B)` of the global shard space (None = host all).
    pub shard_range: Option<Range<u32>>,
    pub placement_epoch: u64,
    pub serve_threads: usize,
    pub pipeline_depth: usize,
    pub leave_policy: LeavePolicy,
    pub checkpoint_path: Option<PathBuf>,
    pub checkpoint_every: u64,
    pub resume: Option<PathBuf>,
    pub status_addr: Option<String>,
    pub retention: RetentionPolicy,
    pub encodings: EncodingSet,
    /// Math kernel backend (`--kernels`, manifest `"kernels"`): `auto`
    /// picks the widest SIMD the host supports; pinning an unavailable
    /// backend fails the launch closed.
    pub kernels: KernelChoice,
    pub metrics_every: u64,
    pub artifacts_dir: PathBuf,
    /// `Some` = this process is a hot standby, not a primary.
    pub standby: Option<StandbyOf>,
}

impl ServeSpec {
    /// The spec for the named `servers[]` or `standbys[]` entry of a
    /// validated manifest.  Checkpoint paths resolve against `run_dir`
    /// (mutable state never resolves against the committed manifest's
    /// directory).  A standby inherits its primary's checkpoint base and
    /// retention — that shared archive series IS the takeover channel.
    pub fn from_manifest(
        m: &ClusterManifest,
        name: &str,
        run_dir: &Path,
    ) -> anyhow::Result<ServeSpec> {
        let workload = match &m.model {
            crate::cluster::manifest::ModelSpec::Synthetic { .. } => Workload::C10,
            crate::cluster::manifest::ModelSpec::Workload(w) => *w,
        };
        let workers = m.fleet.as_ref().map(|f| f.workers).unwrap_or(8);
        let common = |listen: String, status_addr: Option<String>| ServeSpec {
            listen,
            algorithm: m.algorithm,
            workload,
            synthetic_k: m.synthetic_k(),
            workers,
            epochs: m.epochs,
            seed: m.seed,
            eta: m.eta,
            gamma: m.gamma,
            shards: m.shards as usize,
            shard_range: None,
            placement_epoch: 0,
            serve_threads: 1,
            pipeline_depth: m.pipeline_depth,
            leave_policy: m.leave_policy,
            checkpoint_path: None,
            checkpoint_every: 0,
            resume: None,
            status_addr,
            retention: RetentionPolicy::default(),
            encodings: m.encodings,
            kernels: m.kernels,
            metrics_every: m.metrics_every,
            artifacts_dir: crate::config::default_artifacts_dir(),
            standby: None,
        };
        if let Some(s) = m.server(name) {
            let mut spec = common(s.listen.clone(), s.status_addr.clone());
            spec.shard_range = Some(s.shard_range.clone());
            spec.placement_epoch = s.placement_epoch;
            spec.serve_threads = s.serve_threads;
            if let Some(ck) = &s.checkpoint {
                spec.checkpoint_path = Some(ClusterManifest::resolve_run_path(run_dir, &ck.path));
                spec.checkpoint_every = ck.every;
                spec.retention =
                    RetentionPolicy { keep_last: ck.keep_last, keep_hourly: ck.keep_hourly };
            }
            return Ok(spec);
        }
        if let Some(sb) = m.standby(name) {
            let primary = m
                .server(&sb.of)
                .expect("manifest validation pairs every standby with a primary");
            let ck = primary
                .checkpoint
                .as_ref()
                .expect("manifest validation requires the watched primary to archive");
            let mut spec = common(sb.listen.clone(), sb.status_addr.clone());
            spec.serve_threads = primary.serve_threads;
            spec.checkpoint_path = Some(ClusterManifest::resolve_run_path(run_dir, &ck.path));
            spec.checkpoint_every = ck.every;
            spec.retention =
                RetentionPolicy { keep_last: ck.keep_last, keep_hourly: ck.keep_hourly };
            spec.standby = Some(StandbyOf {
                primary: format!("tcp://{}", primary.listen),
                poll_ms: sb.poll_ms,
                miss_budget: sb.miss_budget,
            });
            return Ok(spec);
        }
        anyhow::bail!(
            "cluster manifest has no server or standby named {name:?} (servers: {}; standbys: {})",
            m.servers.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", "),
            m.standbys.iter().map(|s| s.name.as_str()).collect::<Vec<_>>().join(", "),
        )
    }
}

//! Experiment configuration: presets mirroring the paper's Appendix A.5
//! hyperparameters, JSON-file loading and CLI overrides.
//!
//! A [`TrainConfig`] fully describes one training run: which AOT variant to
//! execute, which dataset proxy, which algorithm, the cluster (N workers,
//! homo/hetero), the schedule, and the step budget.  Experiments construct
//! these from presets; the `dana train` CLI can also read one from a JSON
//! file and override fields with flags.

use crate::optim::{AlgorithmKind, LeavePolicy, ScheduleConfig};
use crate::sim::{ChurnSchedule, Environment};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

pub mod serve;

pub use serve::{ServeSpec, StandbyOf};

/// Which workload (model + dataset proxy) to train.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// ResNet-20 / CIFAR-10 proxy (`mlp_c10*` artifacts).
    C10,
    /// WRN-16-4 / CIFAR-10 proxy (`mlp_wrn10_ref`): same dataset as C10,
    /// wider student.
    WrnC10,
    /// WRN-16-4 / CIFAR-100 proxy (`mlp_c100_ref`).
    C100,
    /// ResNet-50 / ImageNet proxy (`mlp_inet_ref`).
    ImageNet,
    /// Char-LM end-to-end workload (`lm_small_ref`).
    LmSmall,
}

impl Workload {
    pub const ALL: [Workload; 5] = [
        Workload::C10,
        Workload::WrnC10,
        Workload::C100,
        Workload::ImageNet,
        Workload::LmSmall,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Workload::C10 => "c10",
            Workload::WrnC10 => "wrn_c10",
            Workload::C100 => "c100",
            Workload::ImageNet => "imagenet",
            Workload::LmSmall => "lm",
        }
    }

    /// Default per-worker batch size for the workload.
    pub fn default_batch(self) -> usize {
        match self {
            Workload::C10 | Workload::WrnC10 | Workload::C100 => 128,
            Workload::ImageNet => 64,
            Workload::LmSmall => 16,
        }
    }
}

impl std::str::FromStr for Workload {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "c10" | "cifar10" => Ok(Workload::C10),
            "wrn_c10" | "wrn10" => Ok(Workload::WrnC10),
            "c100" | "cifar100" => Ok(Workload::C100),
            "imagenet" | "inet" => Ok(Workload::ImageNet),
            "lm" | "lm_small" => Ok(Workload::LmSmall),
            other => {
                anyhow::bail!("unknown workload {other:?} (c10|wrn_c10|c100|imagenet|lm)")
            }
        }
    }
}

/// Everything needed to run one training experiment.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub workload: Workload,
    pub algorithm: AlgorithmKind,
    pub n_workers: usize,
    pub env: Environment,
    pub epochs: f64,
    pub schedule: ScheduleConfig,
    /// Use the Pallas-kernel artifact variant (validation path) instead of
    /// the pure-jnp reference build.
    pub use_pallas: bool,
    /// Per-worker batch override (None = workload default). Only the C10
    /// workload ships alternate-batch artifacts (b32/b64/b256) — used by
    /// the Fig 9 / Table 1 total-batch-size scaling experiments.
    pub batch_override: Option<usize>,
    pub seed: u64,
    pub artifacts_dir: PathBuf,
    /// Record gap/lag metrics every n master steps (0 = off).
    pub metrics_every: u64,
    /// Evaluate every n epochs (0 = only at the end).
    pub eval_every_epochs: f64,
    /// Parameter-server shards S (1 = monolithic master; >1 splits θ and
    /// all per-worker state into S contiguous shards applied in parallel).
    pub shards: usize,
    /// Cluster-membership churn events, pinned to fractions of the run
    /// (empty = fixed membership, bit-for-bit the pre-elastic behavior).
    /// CLI/JSON spec grammar: `"leave@0.3:2,join@0.5,slow@0.6:0=4x"`.
    pub churn: ChurnSchedule,
    /// What happens to a leaver's momentum (DANA family): retired from v⁰
    /// or folded into a surviving worker's slot.
    pub leave_policy: LeavePolicy,
    /// Remote parameter server (`tcp://host:port` or `host:port`) started
    /// with `dana serve`.  None = in-process master.  When set, the
    /// trainers connect a [`crate::net::RemoteMaster`] instead of
    /// constructing a local server; `shards` is then a server-side
    /// setting and this field supersedes it.
    pub master_addr: Option<String>,
    /// Move remote parameter traffic as per-shard `PullShard`/`PushShard`
    /// frames (pipelined; bit-for-bit equivalent to monolithic frames —
    /// see DESIGN.md §9).  Only meaningful with `master_addr` against a
    /// server running `--shards > 1`; a no-op otherwise.
    pub shard_frames: bool,
    /// Worker pipeline depth D: each worker keeps D+1 batches in flight,
    /// overlapping its push/pull round trip with the next gradient
    /// computation, at the cost of D extra *own* steps of (known,
    /// deterministic) staleness — which DANA's look-ahead extrapolates
    /// for (`Algorithm::set_staleness_hint`).  0 = the classic
    /// synchronous pull→compute→push cycle, bit-for-bit (DESIGN.md §10).
    pub pipeline_depth: usize,
    /// Simulated pull→params round-trip time in the gamma clock's units
    /// (`--rtt`; sim drivers only).  0 = communication is free, the
    /// classic schedule.  With rtt > 0 the completion schedule charges a
    /// depth-0 worker one rtt per cycle and lets a pipelined worker hide
    /// it behind compute — the timing half of the pipeline model.
    pub rtt: f64,
    /// Crash-loop supervision budget (`--max-restarts`; real-thread
    /// driver): a worker thread that dies is restarted in place up to
    /// this many times before being permanently retired as lost.  0 =
    /// the classic retire-on-first-death behavior, bit-for-bit.
    pub max_restarts: u32,
    /// Base supervision backoff in milliseconds (`--restart-backoff-ms`):
    /// restart attempt `a` waits `base << (a-1)`, capped at 5 s.
    pub restart_backoff_ms: u64,
    /// Requested gradient payload encoding (`--encoding`; wire v4):
    /// `none` (exact f32, the default), `f16`/`bf16` quantization, or
    /// `topk:K` sparsification with worker-side error feedback.  Over
    /// the wire the request is granted only if the server advertises it
    /// (falling back to `none`); in-process drivers apply the same
    /// transform push-side so compression runs can be simulated without
    /// a server.
    pub encoding: crate::net::Encoding,
    /// Math kernel backend (`--kernels`, JSON `"kernels"`): `auto`
    /// detects the widest SIMD available; a pinned backend fails the run
    /// closed when the host cannot execute it.  Every backend is
    /// bit-for-bit identical, so this is a pure performance switch.
    pub kernels: crate::math::KernelChoice,
}

impl TrainConfig {
    /// Paper-preset for one workload at N workers.
    ///
    /// Schedules are the Appendix A.5 recipes with the epoch axis scaled to
    /// proxy length (DESIGN.md §3): the CIFAR recipe's 160 epochs with decay
    /// at [80, 120] becomes `epochs` with decays at [1/2, 3/4]; warmup stays
    /// 5/160 of the run. CIFAR-100's WRN recipe decays x0.2 at
    /// [0.3, 0.6, 0.8]; ImageNet decays x0.1 at [1/3, 2/3].
    pub fn preset(workload: Workload, algorithm: AlgorithmKind, n_workers: usize, epochs: f64) -> Self {
        // Base learning rates are the proxy's single-worker-tuned values
        // (the paper's policy: hyperparameters tuned for one worker, reused
        // across cluster sizes). η=0.05 places the proxy's stability margin
        // where ResNet-20+BN's sits under the paper's η=0.1, so the
        // divergence crossovers land at paper-like worker counts — see
        // DESIGN.md §3 and EXPERIMENTS.md §Calibration.
        let (base_eta, gamma, decay_factor, decay_frac): (f32, f32, f32, &[f64]) =
            match workload {
                Workload::C10 => (0.05, 0.9, 0.1, &[0.5, 0.75]),
                // WRN-16-4 recipe: decay x0.2 at 60/120/160 of 200 epochs
                Workload::WrnC10 => (0.05, 0.9, 0.2, &[0.3, 0.6, 0.8]),
                Workload::C100 => (0.05, 0.9, 0.2, &[0.3, 0.6, 0.8]),
                Workload::ImageNet => (0.05, 0.9, 0.1, &[1.0 / 3.0, 2.0 / 3.0]),
                Workload::LmSmall => (0.005, 0.9, 0.1, &[0.75]),
            };
        let (train_size, batch) = match workload {
            Workload::C10 | Workload::WrnC10 => (12_800, 128),
            Workload::C100 => (12_800, 128),
            Workload::ImageNet => (25_600, 64),
            Workload::LmSmall => (8_192, 16),
        };
        let steps_per_epoch = train_size / batch;
        let warmup = (5.0 / 160.0 * epochs).min(epochs * 0.25);
        TrainConfig {
            workload,
            algorithm,
            n_workers,
            env: Environment::Homogeneous,
            epochs,
            schedule: ScheduleConfig {
                base_eta,
                gamma,
                // λ=1 is the proxy-calibrated DC strength (the paper's λ=2
                // at its gradient scale; the Taylor term is cubic in the
                // gradient so it tracks the workload).
                lambda: 1.0,
                warmup_epochs: warmup,
                decay_epochs: decay_frac.iter().map(|f| f * epochs).collect(),
                decay_factor,
                steps_per_epoch,
                n_workers,
            },
            use_pallas: false,
            batch_override: None,
            seed: 1,
            artifacts_dir: default_artifacts_dir(),
            metrics_every: 0,
            eval_every_epochs: 0.0,
            shards: 1,
            churn: ChurnSchedule::default(),
            leave_policy: LeavePolicy::default(),
            master_addr: None,
            shard_frames: false,
            pipeline_depth: 0,
            rtt: 0.0,
            max_restarts: 0,
            restart_backoff_ms: 50,
            encoding: crate::net::Encoding::None,
            kernels: crate::math::KernelChoice::Auto,
        }
    }

    /// Set a per-worker batch override and rescale steps/epoch to match.
    pub fn with_batch(mut self, batch: usize) -> Self {
        let train_size = self.schedule.steps_per_epoch * self.batch();
        self.batch_override = Some(batch);
        self.schedule.steps_per_epoch = train_size / batch;
        self
    }

    /// The AOT artifact this config executes.
    pub fn variant_name(&self) -> String {
        let base = match (self.workload, self.use_pallas) {
            (Workload::C10, true) => "mlp_c10",
            (Workload::C10, false) => "mlp_c10_ref",
            (Workload::WrnC10, _) => "mlp_wrn10_ref",
            (Workload::C100, _) => "mlp_c100_ref",
            (Workload::ImageNet, _) => "mlp_inet_ref",
            (Workload::LmSmall, true) => "lm_small",
            (Workload::LmSmall, false) => "lm_small_ref",
        };
        match self.batch_override {
            Some(b) if b != self.workload.default_batch() => {
                assert!(
                    self.workload == Workload::C10 && !self.use_pallas,
                    "batch-override artifacts exist only for c10 ref"
                );
                format!("mlp_c10_b{b}_ref")
            }
            _ => base.to_string(),
        }
    }

    pub fn total_master_steps(&self) -> u64 {
        (self.epochs * self.schedule.steps_per_epoch as f64).round() as u64
    }

    pub fn batch(&self) -> usize {
        self.batch_override.unwrap_or(self.workload.default_batch())
    }

    /// Every key [`TrainConfig::apply_json`] understands.  The override
    /// walker rejects anything else — a typo'd key used to be silently
    /// ignored, which meant a config file could *look* like it set
    /// `pipeline_depth` while the run quietly used the default.
    pub const JSON_KEYS: &'static [&'static str] = &[
        "workload",
        "algorithm",
        "n_workers",
        "env",
        "epochs",
        "base_eta",
        "gamma",
        "seed",
        "use_pallas",
        "shards",
        "churn",
        "leave_policy",
        "master_addr",
        "shard_frames",
        "pipeline_depth",
        "rtt",
        "max_restarts",
        "restart_backoff_ms",
        "encoding",
        "kernels",
    ];

    /// Apply overrides from a parsed JSON object (keys are optional;
    /// unknown keys are rejected by name — fail-closed, like the wire
    /// decoder and the cluster manifest).
    pub fn apply_json(&mut self, j: &Json) -> anyhow::Result<()> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("config overrides must be a JSON object"))?;
        for k in obj.keys() {
            anyhow::ensure!(
                Self::JSON_KEYS.contains(&k.as_str()),
                "config: unknown key {k:?} (known: {})",
                Self::JSON_KEYS.join(", ")
            );
        }
        if let Some(v) = j.get("workload") {
            self.workload = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("workload must be a string"))?
                .parse()?;
        }
        if let Some(v) = j.get("algorithm") {
            self.algorithm = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("algorithm must be a string"))?
                .parse()?;
        }
        if let Some(v) = j.get("n_workers") {
            self.n_workers = v.as_usize().ok_or_else(|| anyhow::anyhow!("bad n_workers"))?;
            self.schedule.n_workers = self.n_workers;
        }
        if let Some(v) = j.get("env") {
            self.env = v.as_str().ok_or_else(|| anyhow::anyhow!("bad env"))?.parse()?;
        }
        if let Some(v) = j.get("epochs") {
            self.epochs = v.as_f64().ok_or_else(|| anyhow::anyhow!("bad epochs"))?;
        }
        if let Some(v) = j.get("base_eta") {
            self.schedule.base_eta = v.as_f64().ok_or_else(|| anyhow::anyhow!("bad base_eta"))? as f32;
        }
        if let Some(v) = j.get("gamma") {
            self.schedule.gamma = v.as_f64().ok_or_else(|| anyhow::anyhow!("bad gamma"))? as f32;
        }
        if let Some(v) = j.get("seed") {
            self.seed = v.as_usize().ok_or_else(|| anyhow::anyhow!("bad seed"))? as u64;
        }
        if let Some(v) = j.get("use_pallas") {
            self.use_pallas = v.as_bool().ok_or_else(|| anyhow::anyhow!("bad use_pallas"))?;
        }
        if let Some(v) = j.get("shards") {
            self.shards = v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shards"))?;
        }
        if let Some(v) = j.get("churn") {
            self.churn = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("churn must be a spec string"))?
                .parse()?;
        }
        if let Some(v) = j.get("leave_policy") {
            self.leave_policy = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("leave_policy must be a string"))?
                .parse()?;
        }
        if let Some(v) = j.get("master_addr") {
            let addr = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("master_addr must be a string"))?;
            anyhow::ensure!(!addr.is_empty(), "master_addr must not be empty");
            self.master_addr = Some(addr.to_string());
        }
        if let Some(v) = j.get("shard_frames") {
            self.shard_frames =
                v.as_bool().ok_or_else(|| anyhow::anyhow!("bad shard_frames"))?;
        }
        if let Some(v) = j.get("pipeline_depth") {
            self.pipeline_depth =
                v.as_usize().ok_or_else(|| anyhow::anyhow!("bad pipeline_depth"))?;
            anyhow::ensure!(
                self.pipeline_depth < crate::server::MAX_PULL_WINDOW,
                "pipeline_depth {} exceeds the supported window ({})",
                self.pipeline_depth,
                crate::server::MAX_PULL_WINDOW - 1
            );
        }
        if let Some(v) = j.get("rtt") {
            self.rtt = v.as_f64().ok_or_else(|| anyhow::anyhow!("bad rtt"))?;
            anyhow::ensure!(
                self.rtt.is_finite() && self.rtt >= 0.0,
                "rtt must be finite and >= 0"
            );
        }
        if let Some(v) = j.get("max_restarts") {
            self.max_restarts =
                v.as_usize().ok_or_else(|| anyhow::anyhow!("bad max_restarts"))? as u32;
        }
        if let Some(v) = j.get("restart_backoff_ms") {
            self.restart_backoff_ms =
                v.as_usize().ok_or_else(|| anyhow::anyhow!("bad restart_backoff_ms"))? as u64;
        }
        if let Some(v) = j.get("encoding") {
            self.encoding = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("encoding must be a string"))?
                .parse()?;
        }
        if let Some(v) = j.get("kernels") {
            self.kernels = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("kernels must be a string"))?
                .parse()?;
        }
        Ok(())
    }

    pub fn from_json_file(path: &Path) -> anyhow::Result<TrainConfig> {
        let j = Json::parse_file(path)?;
        let mut cfg = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        cfg.apply_json(&j)?;
        Ok(cfg)
    }

    /// The fleet's training config for a cluster manifest: the same
    /// preset-plus-overrides normalization the CLI flags go through, so
    /// `dana train --manifest` and a hand-rolled flag invocation produce
    /// identical configs.  The master address is the manifest's full
    /// endpoint list (primaries then standbys), so resolution and
    /// fail-over see the whole topology.  The schedule is built from the
    /// *manifest-wide* hyperparameters — the same ones every server's
    /// [`ServeSpec`](crate::config::ServeSpec) uses — because schedule
    /// agreement across the placement is config, not negotiated.
    pub fn from_manifest(
        m: &crate::cluster::manifest::ClusterManifest,
    ) -> anyhow::Result<TrainConfig> {
        use crate::cluster::manifest::ModelSpec;
        let workload = match &m.model {
            // synthetic runs still carry a schedule; the c10 preset is
            // the schedule donor, exactly as the serve/train CLI default
            ModelSpec::Synthetic { .. } => Workload::C10,
            ModelSpec::Workload(w) => *w,
        };
        let fleet = m.fleet.as_ref();
        let workers = fleet.map(|f| f.workers).unwrap_or(8);
        let mut cfg = TrainConfig::preset(workload, m.algorithm, workers, m.epochs);
        cfg.seed = fleet.map(|f| f.seed).unwrap_or(m.seed);
        if let Some(eta) = m.eta {
            cfg.schedule.base_eta = eta;
        }
        if let Some(g) = m.gamma {
            cfg.schedule.gamma = g;
        }
        cfg.pipeline_depth = m.pipeline_depth;
        cfg.leave_policy = m.leave_policy;
        cfg.kernels = m.kernels;
        cfg.master_addr = Some(m.master_list());
        if let Some(f) = fleet {
            cfg.epochs = f.epochs;
            cfg.encoding = f.encoding;
            cfg.churn = f.churn.clone();
            cfg.leave_policy = f.leave_policy;
            cfg.max_restarts = f.max_restarts;
            cfg.restart_backoff_ms = f.restart_backoff_ms;
            cfg.metrics_every = f.metrics_every;
        }
        Ok(cfg)
    }
}

/// `$DANA_ARTIFACTS` or `<crate root>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("DANA_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_scales_schedule() {
        let c = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        assert_eq!(c.schedule.decay_epochs, vec![10.0, 15.0]);
        assert_eq!(c.schedule.steps_per_epoch, 100);
        assert_eq!(c.total_master_steps(), 2000);
        assert!((c.schedule.warmup_epochs - 0.625).abs() < 1e-9);
    }

    #[test]
    fn workload_parse_round_trip() {
        for w in [Workload::C10, Workload::C100, Workload::ImageNet, Workload::LmSmall] {
            assert_eq!(w.name().parse::<Workload>().unwrap(), w);
        }
    }

    #[test]
    fn json_overrides_apply() {
        let mut c = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        assert_eq!(c.shards, 1, "preset must default to the monolithic master");
        assert!(c.churn.is_empty(), "preset must default to fixed membership");
        assert_eq!(c.leave_policy, LeavePolicy::Retire);
        let j = Json::parse(
            r#"{"algorithm":"nag-asgd","n_workers":16,"env":"hetero","gamma":0.95,"shards":8,
                "churn":"leave@0.3:2,join@0.5","leave_policy":"fold"}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.algorithm, AlgorithmKind::NagAsgd);
        assert_eq!(c.n_workers, 16);
        assert_eq!(c.schedule.n_workers, 16);
        assert_eq!(c.env, Environment::Heterogeneous);
        assert_eq!(c.schedule.gamma, 0.95);
        assert_eq!(c.shards, 8);
        assert_eq!(c.churn.events.len(), 2);
        assert_eq!(c.leave_policy, LeavePolicy::Fold);
    }

    #[test]
    fn pipeline_depth_applies_from_json() {
        let mut c = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        assert_eq!(c.pipeline_depth, 0, "preset must default to the synchronous cycle");
        assert_eq!(c.rtt, 0.0, "preset must default to free communication");
        let j = Json::parse(r#"{"pipeline_depth":2,"rtt":32.5}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.pipeline_depth, 2);
        assert_eq!(c.rtt, 32.5);
        let j = Json::parse(r#"{"pipeline_depth":1000}"#).unwrap();
        assert!(c.apply_json(&j).is_err(), "absurd depth rejected");
        let j = Json::parse(r#"{"rtt":-1.0}"#).unwrap();
        assert!(c.apply_json(&j).is_err(), "negative rtt rejected");
    }

    #[test]
    fn supervision_knobs_apply_from_json() {
        let mut c = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        assert_eq!(c.max_restarts, 0, "preset must default to retire-on-first-death");
        assert_eq!(c.restart_backoff_ms, 50);
        let j = Json::parse(r#"{"max_restarts":3,"restart_backoff_ms":10}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.max_restarts, 3);
        assert_eq!(c.restart_backoff_ms, 10);
        let j = Json::parse(r#"{"max_restarts":"lots"}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn master_addr_applies_from_json() {
        let mut c = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        assert!(c.master_addr.is_none(), "preset must default to in-process");
        assert!(!c.shard_frames, "preset must default to monolithic frames");
        let j = Json::parse(r#"{"master_addr":"tcp://10.0.0.7:7700","shard_frames":true}"#)
            .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.master_addr.as_deref(), Some("tcp://10.0.0.7:7700"));
        assert!(c.shard_frames);
        let j = Json::parse(r#"{"master_addr":""}"#).unwrap();
        assert!(c.apply_json(&j).is_err(), "empty address rejected");
        let j = Json::parse(r#"{"master_addr":42}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn encoding_applies_from_json() {
        use crate::net::Encoding;
        let mut c = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        assert_eq!(c.encoding, Encoding::None, "preset must default to exact f32 frames");
        let j = Json::parse(r#"{"encoding":"f16"}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.encoding, Encoding::F16);
        let j = Json::parse(r#"{"encoding":"topk:64"}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.encoding, Encoding::TopK { k: 64 });
        let j = Json::parse(r#"{"encoding":"mp3"}"#).unwrap();
        assert!(c.apply_json(&j).is_err(), "unknown encoding rejected");
        let j = Json::parse(r#"{"encoding":7}"#).unwrap();
        assert!(c.apply_json(&j).is_err(), "non-string encoding rejected");
    }

    #[test]
    fn bad_churn_spec_errors() {
        let mut c = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        let j = Json::parse(r#"{"churn":"nap@0.5"}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
        let j = Json::parse(r#"{"leave_policy":"meld"}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn bad_json_values_error() {
        let mut c = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        let j = Json::parse(r#"{"algorithm":42}"#).unwrap();
        assert!(c.apply_json(&j).is_err());
    }

    #[test]
    fn unknown_json_key_rejected_by_name() {
        // the exact failure mode this guards: a typo'd key silently
        // ignored, the run quietly using the default depth
        let mut c = TrainConfig::preset(Workload::C10, AlgorithmKind::DanaSlim, 8, 20.0);
        let j = Json::parse(r#"{"pipline_depth":2}"#).unwrap();
        let err = c.apply_json(&j).unwrap_err().to_string();
        assert!(err.contains("unknown key \"pipline_depth\""), "got: {err}");
        assert_eq!(c.pipeline_depth, 0, "typo'd override must not half-apply");
        // non-object override documents are rejected too
        let j = Json::parse(r#"[1,2]"#).unwrap();
        assert!(c.apply_json(&j).is_err());
        // the correctly-spelled key still applies
        let j = Json::parse(r#"{"pipeline_depth":2}"#).unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.pipeline_depth, 2);
    }
}

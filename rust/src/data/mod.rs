//! Synthetic datasets — the paper's CIFAR/ImageNet substitution (DESIGN.md
//! §3) and the char corpus for the end-to-end LM driver.

pub mod synth;
pub mod text;

/// A classification batch: `x` is row-major `f32[B, D]`, `y` is `i32[B]`.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub batch: usize,
}

/// A token batch for LM training: `x`/`y` are `i32[B, T]`.
#[derive(Debug, Clone)]
pub struct TokenBatch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

//! Synthetic char corpus for the end-to-end LM driver.
//!
//! A fixed 2nd-order Markov chain over the `lm_small` vocabulary generates
//! a deterministic corpus with real sequential structure: the chain's
//! transition rows are sparse (few likely successors per bigram), so a
//! competent LM drives per-token loss well below `log(vocab)` — giving the
//! e2e loss curve (EXPERIMENTS.md §E2E) something meaningful to descend.

use super::TokenBatch;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct CharCorpus {
    pub vocab: usize,
    tokens: Vec<i32>,
}

impl CharCorpus {
    /// Generate `len` tokens from a seeded sparse 2nd-order Markov chain.
    pub fn generate(vocab: usize, len: usize, seed: u64) -> Self {
        assert!(vocab >= 4 && len > 16);
        let mut rng = Rng::new(seed);
        // For each bigram state, pick 3 candidate successors with fixed
        // probabilities (0.6 / 0.3 / 0.1): low-entropy but non-trivial.
        let states = vocab * vocab;
        let mut succ = Vec::with_capacity(states * 3);
        for _ in 0..states {
            for _ in 0..3 {
                succ.push(rng.below(vocab as u64) as i32);
            }
        }
        let mut tokens = Vec::with_capacity(len);
        let (mut a, mut b) = (0usize, 1usize);
        for _ in 0..len {
            let u = rng.uniform();
            let slot = if u < 0.6 {
                0
            } else if u < 0.9 {
                1
            } else {
                2
            };
            let next = succ[(a * vocab + b) * 3 + slot];
            tokens.push(next);
            a = b;
            b = next as usize;
        }
        CharCorpus { vocab, tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Sample a `[batch, seq]` window batch: x = tokens[i..i+T],
    /// y = tokens[i+1..i+T+1] (next-token targets).
    pub fn sample_batch(&self, batch: usize, seq: usize, rng: &mut Rng) -> TokenBatch {
        assert!(self.tokens.len() > seq + 1);
        let mut x = Vec::with_capacity(batch * seq);
        let mut y = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.below((self.tokens.len() - seq - 1) as u64) as usize;
            x.extend_from_slice(&self.tokens[start..start + seq]);
            y.extend_from_slice(&self.tokens[start + 1..start + seq + 1]);
        }
        TokenBatch { x, y, batch, seq }
    }

    /// Deterministic evaluation batches from the corpus tail.
    pub fn eval_batches(&self, n_batches: usize, batch: usize, seq: usize) -> Vec<TokenBatch> {
        let mut rng = Rng::new(0xE7A1);
        (0..n_batches).map(|_| self.sample_batch(batch, seq, &mut rng)).collect()
    }

    /// Empirical bigram-conditional entropy (nats) — a floor estimate for
    /// achievable LM loss on this corpus.
    pub fn markov_entropy(&self) -> f64 {
        use std::collections::HashMap;
        let mut counts: HashMap<(i32, i32), HashMap<i32, u32>> = HashMap::new();
        for w in self.tokens.windows(3) {
            *counts
                .entry((w[0], w[1]))
                .or_default()
                .entry(w[2])
                .or_insert(0) += 1;
        }
        let mut total = 0u64;
        let mut ent = 0.0;
        for succ in counts.values() {
            let n: u32 = succ.values().sum();
            for &c in succ.values() {
                let p = c as f64 / n as f64;
                ent -= (c as f64) * p.ln();
                // (weighted later by dividing total)
            }
            total += n as u64;
        }
        // note: ent accumulated c*ln(p) per state; normalize by total count
        ent / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_vocab() {
        let a = CharCorpus::generate(64, 10_000, 1);
        let b = CharCorpus::generate(64, 10_000, 1);
        assert_eq!(a.tokens, b.tokens);
        assert!(a.tokens.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn batches_shift_by_one() {
        let c = CharCorpus::generate(32, 5_000, 2);
        let mut rng = Rng::new(3);
        let b = c.sample_batch(4, 16, &mut rng);
        assert_eq!(b.x.len(), 64);
        // each row: y[t] == x[t+1] (within the same row window)
        for row in 0..4 {
            for t in 0..15 {
                assert_eq!(b.y[row * 16 + t], b.x[row * 16 + t + 1]);
            }
        }
    }

    #[test]
    fn corpus_is_compressible() {
        // Sparse successors => entropy well below uniform ln(64)=4.16.
        let c = CharCorpus::generate(64, 200_000, 4);
        let h = c.markov_entropy();
        assert!(h < 1.5, "markov entropy {h}");
        assert!(h > 0.2, "markov entropy suspiciously low {h}");
    }

    #[test]
    fn eval_batches_are_reproducible() {
        let c = CharCorpus::generate(64, 5_000, 5);
        let a = c.eval_batches(2, 4, 16);
        let b = c.eval_batches(2, 4, 16);
        assert_eq!(a[0].x, b[0].x);
        assert_eq!(a[1].y, b[1].y);
    }
}

//! Gaussian-mixture synthetic classification datasets — the CIFAR/ImageNet
//! proxies (DESIGN.md §3).
//!
//! Each class is an isotropic Gaussian around a random centroid; `noise`
//! sets the overlap (and thus the achievable test error), `label_noise`
//! adds an irreducible floor.  The separations are calibrated so the
//! single-worker baseline lands near the paper's baselines (~92% for the
//! CIFAR-10 proxy, ~75% for the 100-class proxies), leaving the full
//! dynamic range for the staleness effects the figures measure: a diverged
//! run drops to chance (10%/1%), exactly as in the paper's tables.
//! Generation is fully deterministic in the seed, so every algorithm trains
//! on an identical stream (the paper's controlled-schedule methodology).

use super::Batch;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SynthSpec {
    pub in_dim: usize,
    pub classes: usize,
    /// Within-class noise stddev (centroids are N(0, I)).
    pub noise: f32,
    pub train_size: usize,
    pub test_size: usize,
    /// Probability a label is resampled uniformly (irreducible error).
    pub label_noise: f64,
    pub seed: u64,
}

impl SynthSpec {
    /// CIFAR-10 proxy (pairs with the `mlp_c10*` / `mlp_wrn10_ref`
    /// artifacts): baseline lands near the paper's 91.6%.
    pub fn c10() -> Self {
        SynthSpec {
            in_dim: 128,
            classes: 10,
            noise: 3.0,
            train_size: 12_800,
            test_size: 2_048,
            label_noise: 0.02,
            seed: 0xC1FA_0010,
        }
    }

    /// CIFAR-100 proxy (pairs with `mlp_c100_ref`): 100 tighter-packed
    /// classes, baseline near the paper's ~77%.
    pub fn c100() -> Self {
        SynthSpec {
            classes: 100,
            noise: 3.2,
            label_noise: 0.05,
            seed: 0xC1FA_0100,
            ..Self::c10()
        }
    }

    /// ImageNet proxy (pairs with `mlp_inet_ref`): more classes, more data.
    pub fn imagenet() -> Self {
        SynthSpec {
            in_dim: 128,
            classes: 100,
            noise: 3.0,
            train_size: 25_600,
            test_size: 4_096,
            label_noise: 0.05,
            seed: 0x1A6E_0001,
        }
    }
}

/// Materialized dataset (train + test splits).
#[derive(Debug, Clone)]
pub struct SynthDataset {
    pub spec: SynthSpec,
    train_x: Vec<f32>,
    train_y: Vec<i32>,
    test_x: Vec<f32>,
    test_y: Vec<i32>,
}

impl SynthDataset {
    pub fn generate(spec: SynthSpec) -> Self {
        let mut rng = Rng::new(spec.seed);
        let mut centers = vec![0.0f32; spec.classes * spec.in_dim];
        rng.fill_normal_f32(&mut centers, 0.0, 1.0);

        // Normalize to unit per-coordinate variance (as image datasets are
        // standardized): keeps the class-separation ratio while holding the
        // loss curvature at the scale the paper's η=0.1 recipe expects.
        let scale = 1.0 / (1.0 + spec.noise * spec.noise).sqrt();
        let gen_split = |n: usize, rng: &mut Rng| {
            let d = spec.in_dim;
            let mut xs = vec![0.0f32; n * d];
            let mut ys = vec![0i32; n];
            for i in 0..n {
                let mut label = rng.below(spec.classes as u64) as usize;
                let c = &centers[label * d..(label + 1) * d];
                let x = &mut xs[i * d..(i + 1) * d];
                for (xj, &cj) in x.iter_mut().zip(c) {
                    *xj = scale * (cj + spec.noise * rng.normal() as f32);
                }
                if rng.uniform() < spec.label_noise {
                    label = rng.below(spec.classes as u64) as usize;
                }
                ys[i] = label as i32;
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen_split(spec.train_size, &mut rng);
        let (test_x, test_y) = gen_split(spec.test_size, &mut rng);
        SynthDataset { spec, train_x, train_y, test_x, test_y }
    }

    pub fn train_size(&self) -> usize {
        self.spec.train_size
    }

    pub fn test_size(&self) -> usize {
        self.spec.test_size
    }

    /// Assemble a train batch from explicit indices.
    pub fn train_batch(&self, indices: &[usize]) -> Batch {
        let d = self.spec.in_dim;
        let mut x = vec![0.0f32; indices.len() * d];
        let mut y = vec![0i32; indices.len()];
        for (b, &idx) in indices.iter().enumerate() {
            x[b * d..(b + 1) * d].copy_from_slice(&self.train_x[idx * d..(idx + 1) * d]);
            y[b] = self.train_y[idx];
        }
        Batch { x, y, batch: indices.len() }
    }

    /// Test batches of exactly `batch` rows (the AOT eval shape); a final
    /// ragged remainder is dropped (test sizes are chosen divisible).
    pub fn test_batches(&self, batch: usize) -> Vec<Batch> {
        let n = self.spec.test_size / batch;
        (0..n)
            .map(|i| {
                let d = self.spec.in_dim;
                let lo = i * batch;
                Batch {
                    x: self.test_x[lo * d..(lo + batch) * d].to_vec(),
                    y: self.test_y[lo..lo + batch].to_vec(),
                    batch,
                }
            })
            .collect()
    }
}

/// Epoch-shuffled batch index stream: each draw pulls the next `batch`
/// indices, reshuffling at epoch boundaries.
#[derive(Debug, Clone)]
pub struct Batcher {
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(train_size: usize, batch: usize, seed: u64) -> Self {
        assert!(batch <= train_size);
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..train_size).collect();
        rng.shuffle(&mut order);
        Batcher { order, cursor: 0, batch, rng }
    }

    pub fn next_indices(&mut self) -> Vec<usize> {
        if self.cursor + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
        }
        let out = self.order[self.cursor..self.cursor + self.batch].to_vec();
        self.cursor += self.batch;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SynthSpec {
        SynthSpec {
            in_dim: 8,
            classes: 4,
            noise: 1.0,
            train_size: 64,
            test_size: 32,
            label_noise: 0.0,
            seed: 7,
        }
    }

    #[test]
    fn deterministic_generation() {
        let a = SynthDataset::generate(tiny());
        let b = SynthDataset::generate(tiny());
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.test_y, b.test_y);
    }

    #[test]
    fn labels_in_range_and_diverse() {
        let d = SynthDataset::generate(tiny());
        let mut seen = vec![false; 4];
        for &y in &d.train_y {
            assert!((0..4).contains(&y));
            seen[y as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all classes present");
    }

    #[test]
    fn low_noise_task_is_nearest_centroid_solvable() {
        // With noise << centroid separation, a nearest-centroid rule on the
        // regenerated centers classifies (almost) perfectly.
        let spec = SynthSpec { noise: 0.05, ..tiny() };
        let data = SynthDataset::generate(spec);
        let mut rng = Rng::new(spec.seed);
        let mut centers = vec![0.0f32; spec.classes * spec.in_dim];
        rng.fill_normal_f32(&mut centers, 0.0, 1.0);
        let batch = data.test_batches(32).remove(0);
        let mut correct = 0;
        for i in 0..32 {
            let x = &batch.x[i * 8..(i + 1) * 8];
            let best = (0..4)
                .min_by(|&a, &b| {
                    let da = crate::math::sub_norm(x, &centers[a * 8..(a + 1) * 8]);
                    let db = crate::math::sub_norm(x, &centers[b * 8..(b + 1) * 8]);
                    da.total_cmp(&db)
                })
                .unwrap();
            if best as i32 == batch.y[i] {
                correct += 1;
            }
        }
        assert!(correct >= 31, "nearest centroid got {correct}/32");
    }

    #[test]
    fn batch_assembly_matches_source() {
        let d = SynthDataset::generate(tiny());
        let b = d.train_batch(&[3, 0]);
        assert_eq!(b.batch, 2);
        assert_eq!(b.x[..8], d.train_x[3 * 8..4 * 8]);
        assert_eq!(b.y[0], d.train_y[3]);
    }

    #[test]
    fn test_batches_tile_the_split() {
        let d = SynthDataset::generate(tiny());
        let bs = d.test_batches(16);
        assert_eq!(bs.len(), 2);
        assert!(bs.iter().all(|b| b.batch == 16));
    }

    #[test]
    fn batcher_covers_epoch_without_repeats() {
        let mut b = Batcher::new(100, 10, 3);
        let mut seen = vec![0u32; 100];
        for _ in 0..10 {
            for i in b.next_indices() {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "first epoch must be a permutation");
    }

    #[test]
    fn batcher_reshuffles_across_epochs() {
        let mut b = Batcher::new(20, 20, 3);
        let e1 = b.next_indices();
        let e2 = b.next_indices();
        assert_ne!(e1, e2);
    }
}
